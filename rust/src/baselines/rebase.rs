//! Rebase-style reward-guided tree search (baseline; Wu et al. 2024).
//!
//! The original Rebase maintains a tree of reasoning prefixes with at
//! most N leaves, iteratively expanding high-reward nodes under a PRM.
//! Our engine stores KV in fixed slots without fork support, so node
//! expansion *replays* the parent's prefix (prompt prefill + teacher-
//! forced decode of the shared tokens) into a fresh slot — an explicit
//! materialization of the search's re-exploration cost. This preserves
//! the serving-relevant behaviour the paper reports (§5.2): as responses
//! grow to thousands of tokens the search space (and the cost of
//! re-visiting prefixes) blows up, so Rebase's latency scales poorly and
//! its accuracy degrades relative to straight branch sampling.
//!
//! Scheduling skeleton mirrors Algorithm 1's loop (continuous batching,
//! FCFS admission, KV-budget gating) so all methods share the substrate.

use crate::coordinator::{ClockHandle, RequestOutcome};
use crate::engine::{Engine, PrefillEntry, ReplayEntry, SlotId};
use crate::kvcache::{AdmissionRequest, KvCacheManager};
use crate::metrics::{Timeline, TimelinePoint};
use crate::prm::PrmScorer;
use crate::tokenizer as tok;
use crate::tokenizer::Token;
use crate::util::rng::Rng;
use crate::workload::{chain_state, Request};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;

/// Rebase knobs.
#[derive(Debug, Clone)]
pub struct RebaseConfig {
    /// Leaf budget (the paper's N).
    pub n_leaves: usize,
    /// Decode steps between reallocation rounds.
    pub t_round: usize,
    pub temperature: f32,
    pub max_new: usize,
    /// Softmax temperature over rewards for leaf reallocation.
    pub reward_tau: f64,
    /// Total branch spawn cap per request (guarantees termination).
    pub spawn_cap: usize,
    pub kv_capacity_tokens: usize,
    pub kv_page_tokens: usize,
    pub seed: u64,
}

impl RebaseConfig {
    pub fn with_n(n: usize) -> RebaseConfig {
        RebaseConfig {
            n_leaves: n,
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            reward_tau: 0.2,
            spawn_cap: 3 * n,
            kv_capacity_tokens: 4096,
            kv_page_tokens: 16,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum LeafStatus {
    Queued,
    Running,
    Completed,
    Killed,
}

struct Leaf {
    status: LeafStatus,
    slot: Option<SlotId>,
    kv: Option<crate::kvcache::BranchId>,
    generated: Vec<Token>,
    /// Tokens inherited from the parent at fork time.
    inherited: Vec<Token>,
    seed: u64,
    reward: f32,
}

struct ReqState {
    id: usize,
    question: crate::workload::Question,
    dataset: String,
    arrival: f64,
    admitted_at: Option<f64>,
    finished_at: Option<f64>,
    leaves: Vec<Leaf>,
    completions: Vec<(Option<u8>, f32, usize, f64)>,
    prefix: Option<crate::kvcache::PrefixId>,
    spawned: usize,
    answer: Option<u8>,
}

impl ReqState {
    fn full_tokens(&self, li: usize) -> Vec<Token> {
        let mut s = self.question.prompt_tokens();
        s.extend_from_slice(&self.leaves[li].inherited);
        s.extend_from_slice(&self.leaves[li].generated);
        s
    }

    fn response_len(&self, li: usize) -> usize {
        self.leaves[li].inherited.len() + self.leaves[li].generated.len()
    }
}

/// The Rebase scheduler.
pub struct RebaseScheduler<'e> {
    cfg: RebaseConfig,
    engine: &'e mut dyn Engine,
    prm: &'e mut dyn PrmScorer,
    pub clock: ClockHandle,
    kv: KvCacheManager,
    requests: Vec<ReqState>,
    request_queue: VecDeque<usize>,
    slots: Vec<Option<(usize, usize)>>,
    rng: Rng,
}

impl<'e> RebaseScheduler<'e> {
    pub fn new(
        cfg: RebaseConfig,
        engine: &'e mut dyn Engine,
        prm: &'e mut dyn PrmScorer,
        clock: ClockHandle,
    ) -> RebaseScheduler<'e> {
        let slots = engine.caps().slots;
        let kv = KvCacheManager::new(cfg.kv_capacity_tokens, cfg.kv_page_tokens);
        let rng = Rng::new(cfg.seed ^ 0x5EBA5E);
        RebaseScheduler {
            cfg,
            engine,
            prm,
            clock,
            kv,
            requests: Vec::new(),
            request_queue: VecDeque::new(),
            slots: vec![None; slots],
            rng,
        }
    }

    pub fn serve(&mut self, trace: &[Request])
        -> Result<(Vec<RequestOutcome>, Timeline)> {
        let mut pending: VecDeque<&Request> = trace.iter().collect();
        let mut timeline = Timeline::default();
        // Cumulative prompt-prefill seconds (timeline metric). Replay
        // dispatches (tree forks) are charged to the clock but not here —
        // they are decode-side work, not prompt streaming.
        let mut prefill_seconds = 0.0f64;
        loop {
            let now = self.clock.now();
            while pending.front().map(|r| r.arrival <= now).unwrap_or(false) {
                let r = pending.pop_front().unwrap();
                self.requests.push(ReqState {
                    id: r.id,
                    question: r.question.clone(),
                    dataset: r.dataset.clone(),
                    arrival: r.arrival,
                    admitted_at: None,
                    finished_at: None,
                    leaves: Vec::new(),
                    completions: Vec::new(),
                    prefix: None,
                    spawned: 0,
                    answer: None,
                });
                self.request_queue.push_back(self.requests.len() - 1);
            }

            let prefills = self.fill_batch()?;
            if !prefills.is_empty() {
                let cost = self.engine.prefill(&prefills)?;
                prefill_seconds += cost;
                self.charge(cost);
            }

            let active: Vec<SlotId> = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(s, o)| o.map(|_| s))
                .collect();
            if active.is_empty() {
                if let Some(next) = pending.front() {
                    self.idle_until(next.arrival);
                    continue;
                }
                if self.request_queue.is_empty() {
                    break;
                }
                bail!("rebase stalled: queued requests cannot be admitted");
            }

            let res = self
                .engine
                .decode(&active, self.cfg.t_round, self.cfg.temperature)?;
            self.charge(res.cost);

            let mut involved = Vec::new();
            for (slot, toks) in &res.emitted {
                let Some((ridx, li)) = self.slots[*slot] else {
                    bail!("emitted for empty slot");
                };
                if !involved.contains(&ridx) {
                    involved.push(ridx);
                }
                let leaf = &mut self.requests[ridx].leaves[li];
                leaf.generated.extend_from_slice(toks);
                if let Some(kvb) = leaf.kv {
                    self.kv.note_decode(kvb, toks.len())?;
                }
            }

            self.process_round(&involved)?;

            timeline.points.push(TimelinePoint {
                t: self.clock.now(),
                running_branches: self.slots.iter().filter(|s| s.is_some()).count(),
                // Rebase never streams prefill: every occupied slot
                // decodes.
                decoding_branches: self
                    .slots
                    .iter()
                    .filter(|s| s.is_some())
                    .count(),
                running_tokens: self
                    .requests
                    .iter()
                    .filter(|r| r.finished_at.is_none())
                    .flat_map(|r| {
                        r.leaves.iter().enumerate().filter_map(|(i, l)| {
                            (l.status == LeafStatus::Running)
                                .then(|| r.response_len(i))
                        })
                    })
                    .sum(),
                kv_pages_used: self.kv.used_pages(),
                queued_requests: self.request_queue.len(),
                // The Rebase baseline allocates prompts scalar-style and
                // never consults the cross-request cache; it has no
                // chunked-prefill path either.
                cache_hit_tokens: 0,
                queued_prefill_tokens: 0,
                prefill_seconds,
            });
        }

        let mut outcomes = Vec::new();
        for r in &self.requests {
            let finished_at =
                r.finished_at.with_context(|| format!("req {} unfinished", r.id))?;
            let admitted_at = r.admitted_at.unwrap_or(finished_at);
            outcomes.push(RequestOutcome {
                id: r.id,
                dataset: r.dataset.clone(),
                arrival: r.arrival,
                admitted_at,
                // Rebase prefills monolithically at admission.
                prefill_done_at: admitted_at,
                finished_at,
                answer: r.answer,
                truth: r.question.answer(),
                branches_started: r.spawned,
                branches_pruned: r
                    .leaves
                    .iter()
                    .filter(|l| l.status == LeafStatus::Killed)
                    .count(),
                branches_completed: r.completions.len(),
                tokens_generated: r
                    .leaves
                    .iter()
                    .map(|l| l.generated.len())
                    .sum(),
                response_lengths: r
                    .completions
                    .iter()
                    .map(|c| c.2)
                    .collect(),
                // Rebase never consults the cross-request cache, has no
                // cluster path and never preempts, so none of these can
                // be non-zero.
                cached_prompt_tokens: 0,
                redispatches: 0,
                preemptions: 0,
            });
        }
        self.kv.check_invariants()?;
        Ok((outcomes, timeline))
    }

    fn charge(&self, cost: f64) {
        if let ClockHandle::Sim(c) = &self.clock {
            c.advance(cost);
        }
    }

    fn idle_until(&self, t: f64) {
        match &self.clock {
            ClockHandle::Sim(c) => c.advance_to(t),
            ClockHandle::Real(c) => {
                use crate::util::clock::Clock;
                let dt = t - c.now();
                if dt > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        dt.min(0.01),
                    ));
                }
            }
        }
    }

    fn free_slot(&self) -> Option<SlotId> {
        self.slots.iter().position(|s| s.is_none())
    }

    fn fill_batch(&mut self) -> Result<Vec<PrefillEntry>> {
        use crate::util::clock::Clock as _;
        let now = match &self.clock {
            ClockHandle::Real(c) => c.now(),
            ClockHandle::Sim(c) => c.now(),
        };
        let mut entries = Vec::new();
        // Admit head requests while slots + budget allow; Rebase starts
        // each request with n_leaves root samples.
        while let (Some(&ridx), Some(_)) =
            (self.request_queue.front(), self.free_slot())
        {
            let n = self.cfg.n_leaves;
            let prompt = self.requests[ridx].question.prompt_tokens();
            let Some(adm) = self
                .kv
                .admit(&AdmissionRequest::monolithic(
                    &prompt,
                    self.cfg.max_new,
                    n,
                ))?
                .admitted()
            else {
                break;
            };
            self.request_queue.pop_front();
            let req = &mut self.requests[ridx];
            req.admitted_at = Some(now);
            req.prefix = Some(adm.prefix);
            for kvb in adm.branches {
                let seed = self.rng.next_u64();
                req.leaves.push(Leaf {
                    status: LeafStatus::Queued,
                    slot: None,
                    kv: Some(kvb),
                    generated: Vec::new(),
                    inherited: Vec::new(),
                    seed,
                    reward: f32::NAN,
                });
                req.spawned += 1;
            }
        }
        // Start queued leaves on free slots.
        for ridx in 0..self.requests.len() {
            if self.requests[ridx].finished_at.is_some() {
                continue;
            }
            for li in 0..self.requests[ridx].leaves.len() {
                if self.requests[ridx].leaves[li].status != LeafStatus::Queued {
                    continue;
                }
                let Some(slot) = self.free_slot() else {
                    return Ok(entries);
                };
                let prompt = self.requests[ridx].question.prompt_tokens();
                let leaf = &mut self.requests[ridx].leaves[li];
                leaf.status = LeafStatus::Running;
                leaf.slot = Some(slot);
                self.slots[slot] = Some((ridx, li));
                entries.push(PrefillEntry {
                    slot,
                    prompt,
                    seed: leaf.seed,
                    cached_tokens: 0,
                });
            }
        }
        Ok(entries)
    }

    fn process_round(&mut self, involved: &[usize]) -> Result<()> {
        use crate::util::clock::Clock as _;
        let now = match &self.clock {
            ClockHandle::Real(c) => c.now(),
            ClockHandle::Sim(c) => c.now(),
        };
        // Score all running + just-completed leaves of involved requests.
        let mut queries: Vec<(usize, usize)> = Vec::new();
        for &ridx in involved {
            for li in 0..self.requests[ridx].leaves.len() {
                if self.requests[ridx].leaves[li].status == LeafStatus::Running
                {
                    queries.push((ridx, li));
                }
            }
        }
        if !queries.is_empty() {
            let seqs: Vec<Vec<Token>> = queries
                .iter()
                .map(|&(r, l)| self.requests[r].full_tokens(l))
                .collect();
            let refs: Vec<&[Token]> = seqs.iter().map(|s| s.as_slice()).collect();
            let scores = self.prm.score(&refs)?;
            for (&(r, l), s) in queries.iter().zip(scores) {
                self.requests[r].leaves[l].reward = s;
            }
        }

        for &ridx in involved {
            // Harvest completions / caps.
            for li in 0..self.requests[ridx].leaves.len() {
                let leaf = &self.requests[ridx].leaves[li];
                if leaf.status != LeafStatus::Running {
                    continue;
                }
                let done = leaf.generated.last() == Some(&tok::EOS);
                let capped =
                    self.requests[ridx].response_len(li) >= self.cfg.max_new;
                if !done && !capped {
                    continue;
                }
                let full_len = self.requests[ridx].response_len(li);
                let (answer, reward) = {
                    let mut seq = self.requests[ridx].leaves[li]
                        .inherited
                        .clone();
                    seq.extend_from_slice(
                        &self.requests[ridx].leaves[li].generated,
                    );
                    (tok::extract_answer(&seq),
                     self.requests[ridx].leaves[li].reward)
                };
                self.release_leaf(ridx, li, LeafStatus::Completed)?;
                self.requests[ridx]
                    .completions
                    .push((answer, reward, full_len, now));
            }

            // Reallocate: kill the weakest leaf and fork the strongest when
            // the reward gap is decisive (softmax-weighted draw).
            self.reallocate(ridx)?;

            // Finalize when the leaf budget has fully completed or nothing
            // is left to run.
            let req = &self.requests[ridx];
            let live = req
                .leaves
                .iter()
                .any(|l| matches!(l.status, LeafStatus::Running | LeafStatus::Queued));
            if req.finished_at.is_none()
                && (req.completions.len() >= self.cfg.n_leaves || !live)
                && !req.completions.is_empty()
            {
                // Reward-weighted vote.
                let mut weight = [0.0f64; 10];
                for (ans, r, _, _) in &req.completions {
                    if let Some(a) = ans {
                        weight[*a as usize] += (*r as f64).max(1e-3);
                    }
                }
                let best = weight
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as u8);
                let req = &mut self.requests[ridx];
                req.answer = if weight.iter().any(|&w| w > 0.0) {
                    best
                } else {
                    None
                };
                req.finished_at = Some(now);
                // Release any stragglers.
                for li in 0..self.requests[ridx].leaves.len() {
                    if matches!(self.requests[ridx].leaves[li].status,
                                LeafStatus::Running | LeafStatus::Queued) {
                        self.release_leaf(ridx, li, LeafStatus::Killed)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Kill-and-fork reallocation over the running leaves of one request.
    fn reallocate(&mut self, ridx: usize) -> Result<()> {
        if self.requests[ridx].finished_at.is_some() {
            return Ok(());
        }
        if self.requests[ridx].spawned >= self.cfg.spawn_cap {
            return Ok(());
        }
        let running: Vec<usize> = self.requests[ridx]
            .leaves
            .iter()
            .enumerate()
            .filter(|(_, l)| l.status == LeafStatus::Running)
            .map(|(i, _)| i)
            .collect();
        if running.len() < 2 {
            return Ok(());
        }
        // Softmax weights over rewards.
        let rewards: Vec<f64> = running
            .iter()
            .map(|&li| self.requests[ridx].leaves[li].reward as f64)
            .collect();
        if rewards.iter().any(|r| r.is_nan()) {
            return Ok(());
        }
        let max_r = rewards.iter().cloned().fold(f64::MIN, f64::max);
        let weights: Vec<f64> = rewards
            .iter()
            .map(|r| ((r - max_r) / self.cfg.reward_tau).exp())
            .collect();
        // Draw a multinomial allocation of the running count.
        let mut alloc = vec![0usize; running.len()];
        for _ in 0..running.len() {
            alloc[self.rng.weighted(&weights)] += 1;
        }
        // Kill leaves with 0 allocation; fork leaves with >1 (one extra
        // child per surplus, slot- and budget-permitting).
        let mut replays: Vec<ReplayEntry> = Vec::new();
        for (pos, &li) in running.iter().enumerate() {
            if alloc[pos] == 0 {
                self.release_leaf(ridx, li, LeafStatus::Killed)?;
            }
        }
        for (pos, &li) in running.iter().enumerate() {
            let mut surplus = alloc[pos].saturating_sub(1);
            while surplus > 0 && self.requests[ridx].spawned < self.cfg.spawn_cap {
                let Some(slot) = self.free_slot() else {
                    break;
                };
                // Fork point: the parent's trajectory truncated to the last
                // complete derivation step.
                let parent_tokens: Vec<Token> = {
                    let l = &self.requests[ridx].leaves[li];
                    let mut t = l.inherited.clone();
                    t.extend_from_slice(&l.generated);
                    t
                };
                let fork = truncate_to_step_boundary(
                    &self.requests[ridx].question, &parent_tokens);
                if fork.is_empty() {
                    break; // nothing worth inheriting yet
                }
                let Some(grown) = self
                    .kv
                    .admit(&AdmissionRequest::grow(
                        self.requests[ridx].prefix.unwrap(),
                        self.cfg.max_new,
                        1,
                    ))?
                    .admitted()
                else {
                    break; // memory-gated
                };
                let kvbs = grown.branches;
                let seed = self.rng.next_u64();
                let prompt = self.requests[ridx].question.prompt_tokens();
                let req = &mut self.requests[ridx];
                req.leaves.push(Leaf {
                    status: LeafStatus::Running,
                    slot: Some(slot),
                    kv: Some(kvbs[0]),
                    generated: Vec::new(),
                    inherited: fork.clone(),
                    seed,
                    reward: f32::NAN,
                });
                req.spawned += 1;
                let new_li = req.leaves.len() - 1;
                self.slots[slot] = Some((ridx, new_li));
                replays.push(ReplayEntry { slot, prompt, forced: fork, seed });
                surplus -= 1;
            }
        }
        if !replays.is_empty() {
            let cost = self.engine.replay(&replays)?;
            self.charge(cost);
        }
        Ok(())
    }

    fn release_leaf(
        &mut self,
        ridx: usize,
        li: usize,
        status: LeafStatus,
    ) -> Result<()> {
        let leaf = &mut self.requests[ridx].leaves[li];
        leaf.status = status;
        if let Some(slot) = leaf.slot.take() {
            self.slots[slot] = None;
            self.engine.release(slot);
        }
        if let Some(kvb) = leaf.kv.take() {
            self.kv.release_branch(kvb)?;
        }
        Ok(())
    }
}

/// Longest prefix of `generated` ending at a complete `<step> c = n`
/// boundary that still parses as a consistent chain (fork point).
fn truncate_to_step_boundary(
    q: &crate::workload::Question,
    generated: &[Token],
) -> Vec<Token> {
    // Walk back until chain_state parses.
    let mut end = generated.len();
    while end > 0 {
        if chain_state(q, &generated[..end]).is_some() {
            return generated[..end].to_vec();
        }
        end -= 1;
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim::{SimCostModel, SimEngine};
    use crate::prm::OraclePrm;
    use crate::util::clock::SimClock;
    use crate::workload::{batch_trace, TaskSpec};

    fn run(n: usize, reqs: usize, seed: u64) -> Vec<RequestOutcome> {
        let spec = TaskSpec::synth_gaokao();
        let trace = batch_trace(&spec, reqs, seed);
        let mut engine =
            SimEngine::new(8, 256, spec, SimCostModel::default());
        let mut prm = OraclePrm::new(0.08, seed);
        let mut cfg = RebaseConfig::with_n(n);
        cfg.kv_capacity_tokens = 8192;
        cfg.seed = seed;
        let mut sched = RebaseScheduler::new(
            cfg, &mut engine, &mut prm, ClockHandle::Sim(SimClock::new()));
        sched.serve(&trace).unwrap().0
    }

    #[test]
    fn rebase_serves_all() {
        let outs = run(4, 8, 1);
        assert_eq!(outs.len(), 8);
        for o in &outs {
            assert!(o.finished_at > o.arrival);
            assert!(o.branches_completed > 0);
        }
    }

    #[test]
    fn rebase_respects_spawn_cap() {
        let outs = run(4, 8, 2);
        for o in &outs {
            assert!(o.branches_started <= 12, "spawned {}", o.branches_started);
        }
    }

    #[test]
    fn rebase_answers_mostly() {
        let outs = run(4, 20, 3);
        let answered = outs.iter().filter(|o| o.answer.is_some()).count();
        assert!(answered >= 18, "answered {answered}/20");
    }

    #[test]
    fn fork_point_parses() {
        let mut rng = Rng::new(5);
        let q = crate::workload::Question::sample(
            &TaskSpec::synth_gaokao(), &mut rng);
        let resp = crate::workload::sample_response(
            &q, &TaskSpec::synth_gaokao(), &mut rng, 256);
        // Truncations of a valid response parse to some boundary.
        let fork = truncate_to_step_boundary(&q, &resp[..resp.len() / 2]);
        assert!(chain_state(&q, &fork).is_some() || fork.is_empty());
    }
}
