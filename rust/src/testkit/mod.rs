//! In-repo property-testing helper.
//!
//! The offline registry has no `proptest`, so this provides the subset we
//! need: seeded random case generation, a fixed case budget, and
//! shrink-lite reporting (on failure, the failing seed is printed so the
//! case replays deterministically — `SART_PROP_SEED=<seed>` reruns just
//! that case). Property tests over coordinator invariants live in
//! `rust/tests/properties.rs`.

use crate::util::rng::Rng;

/// Number of cases per property (overridable via `SART_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("SART_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded inputs. On failure, panics with the
/// case seed for replay. If `SART_PROP_SEED` is set, runs only that case.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed_str) = std::env::var("SART_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("SART_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at replayed seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        // Decorrelate case seeds; keep them printable/replayable.
        let seed = case
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0x5851_F42D_4C95_7F2D);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} \
                 (replay with SART_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Micro-benchmark support for the `harness = false` bench targets
/// (criterion is unavailable offline; this prints the same headline
/// numbers: mean / p50 / p95 per iteration).
pub mod bench {
    use crate::util::stats::{percentile, mean};
    use std::time::Instant;

    /// Time `iters` runs of `f` after `warmup` runs; print a stats row.
    pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e6); // µs
        }
        println!(
            "{name:<44} {:>10.1} µs/iter  p50 {:>10.1}  p95 {:>10.1}  (n={iters})",
            mean(&samples),
            percentile(&samples, 50.0),
            percentile(&samples, 95.0),
        );
    }

    /// Like [`run`] but for fallible bodies; panics on error.
    pub fn run_result<F: FnMut() -> anyhow::Result<()>>(
        name: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) {
        run(name, warmup, iters, || f().expect("bench body failed"));
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("trivial", 16, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `bad` failed")]
    fn check_fails_with_seed_report() {
        check("bad", 16, |rng| {
            let x = rng.below(10);
            if x < 5 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }
}
