//! In-repo property-testing and micro-benchmark helpers.
//!
//! The offline registry has no `proptest`, so this provides the subset we
//! need: seeded random case generation, a fixed case budget, and
//! shrink-lite reporting (on failure, the failing seed is printed so the
//! case replays deterministically — `SART_PROP_SEED=<seed>` reruns just
//! that case). Property tests over coordinator invariants live in
//! `rust/tests/properties.rs`.

use crate::util::rng::Rng;

/// Number of cases per property (overridable via `SART_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("SART_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` seeded inputs. On failure, panics with the
/// case seed for replay. If `SART_PROP_SEED` is set, runs only that case.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed_str) = std::env::var("SART_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("SART_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at replayed seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        // Decorrelate case seeds; keep them printable/replayable.
        let seed = case
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0x5851_F42D_4C95_7F2D);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} \
                 (replay with SART_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Micro-benchmark support for the `harness = false` bench targets
/// (criterion is unavailable offline; this prints the same headline
/// numbers: mean / p50 / p95 per iteration) and serializes every run to
/// a machine-readable `BENCH_<name>.json` at the repo root so the perf
/// trajectory is tracked PR over PR (see EXPERIMENTS.md §Benches).
///
/// Environment knobs:
/// * `SART_BENCH_ITERS` — upper bound on iterations per bench (CI smoke
///   runs use a small value; statistics stay valid, just noisier);
/// * `SART_BENCH_DIR` — output directory for the JSON reports (defaults
///   to the repo root, i.e. the parent of the cargo manifest dir).
pub mod bench {
    use crate::util::json::Json;
    use crate::util::stats::{mean, percentile};
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::time::Instant;

    /// One measured bench row (all times in microseconds per iteration).
    #[derive(Debug, Clone)]
    pub struct BenchResult {
        pub name: String,
        pub iters: usize,
        pub mean_us: f64,
        pub p50_us: f64,
        pub p95_us: f64,
    }

    /// Cap `iters` by the `SART_BENCH_ITERS` env knob (min 1).
    fn effective_iters(iters: usize) -> usize {
        std::env::var("SART_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map(|cap| iters.min(cap))
            .unwrap_or(iters)
            .max(1)
    }

    /// Time `iters` runs of `f` after `warmup` runs; print a stats row
    /// and return the measurement for report serialization.
    pub fn run<F: FnMut()>(
        name: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> BenchResult {
        run_timed(name, warmup, iters, || {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e6 // µs
        })
    }

    /// Like [`run`] but `f` reports its own measured microseconds —
    /// for bodies that need untimed setup between samples (e.g.
    /// re-prefilling engine slots so a decode bench never times prefill).
    pub fn run_timed<F: FnMut() -> f64>(
        name: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> BenchResult {
        let iters = effective_iters(iters);
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            samples.push(f());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_us: mean(&samples),
            p50_us: percentile(&samples, 50.0),
            p95_us: percentile(&samples, 95.0),
        };
        println!(
            "{name:<44} {:>10.1} µs/iter  p50 {:>10.1}  p95 {:>10.1}  (n={iters})",
            res.mean_us, res.p50_us, res.p95_us,
        );
        res
    }

    /// Like [`run`] but for fallible bodies; panics on error.
    pub fn run_result<F: FnMut() -> anyhow::Result<()>>(
        name: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> BenchResult {
        run(name, warmup, iters, || f().expect("bench body failed"))
    }

    /// Accumulates bench rows plus named scalar metrics and writes them
    /// as `BENCH_<name>.json` (schema documented in EXPERIMENTS.md).
    #[derive(Debug, Clone)]
    pub struct BenchReport {
        name: String,
        results: Vec<BenchResult>,
        metrics: BTreeMap<String, f64>,
    }

    impl BenchReport {
        pub fn new(name: &str) -> BenchReport {
            BenchReport {
                name: name.to_string(),
                results: Vec::new(),
                metrics: BTreeMap::new(),
            }
        }

        pub fn push(&mut self, r: BenchResult) {
            self.results.push(r);
        }

        /// Record a derived scalar (e.g. µs/round at a given scale).
        pub fn metric(&mut self, name: &str, value: f64) {
            self.metrics.insert(name.to_string(), value);
        }

        pub fn to_json(&self) -> Json {
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(), Json::Str(self.name.clone()));
            root.insert(
                "results".to_string(),
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            let mut o = BTreeMap::new();
                            o.insert("name".into(), Json::Str(r.name.clone()));
                            o.insert("iters".into(), Json::Num(r.iters as f64));
                            o.insert("mean_us".into(), Json::Num(r.mean_us));
                            o.insert("p50_us".into(), Json::Num(r.p50_us));
                            o.insert("p95_us".into(), Json::Num(r.p95_us));
                            Json::Obj(o)
                        })
                        .collect(),
                ),
            );
            root.insert(
                "metrics".to_string(),
                Json::Obj(
                    self.metrics
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::Num(v)))
                        .collect(),
                ),
            );
            Json::Obj(root)
        }

        /// Serialize to `<out dir>/BENCH_<name>.json` and return the path.
        pub fn write(&self) -> anyhow::Result<PathBuf> {
            let dir = std::env::var_os("SART_BENCH_DIR")
                .map(PathBuf::from)
                .unwrap_or_else(|| {
                    // The repo root: parent of the rust/ package dir.
                    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                        .parent()
                        .map(|p| p.to_path_buf())
                        .unwrap_or_else(|| PathBuf::from("."))
                });
            let path = dir.join(format!("BENCH_{}.json", self.name));
            let mut text = self.to_json().to_string();
            text.push('\n');
            std::fs::write(&path, text)?;
            println!("wrote {}", path.display());
            Ok(path)
        }
    }
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("trivial", 16, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `bad` failed")]
    fn check_fails_with_seed_report() {
        check("bad", 16, |rng| {
            let x = rng.below(10);
            if x < 5 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    fn bench_run_returns_stats() {
        let r = bench::run("noop", 1, 8, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters.min(8), r.iters);
        assert!(r.mean_us >= 0.0 && r.p50_us >= 0.0 && r.p95_us >= 0.0);
    }

    #[test]
    fn bench_report_serializes_valid_json() {
        use crate::util::json::Json;
        let mut rep = bench::BenchReport::new("unit");
        rep.push(bench::BenchResult {
            name: "x".into(),
            iters: 4,
            mean_us: 1.5,
            p50_us: 1.0,
            p95_us: 2.0,
        });
        rep.metric("us_per_round", 3.25);
        let j = rep.to_json();
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.req("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(
            back.req("results").unwrap().as_arr().unwrap().len(),
            1
        );
        assert_eq!(
            back.req("metrics")
                .unwrap()
                .req("us_per_round")
                .unwrap()
                .as_f64(),
            Some(3.25)
        );
    }
}
