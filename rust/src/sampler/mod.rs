//! Host-side token sampling and answer aggregation.
//!
//! In single-step mode the coordinator samples from the logits the engine
//! reads back, with one deterministic RNG stream per branch. In fused-chunk
//! mode sampling happens in-graph (gumbel argmax with the same
//! temperature semantics); both paths mask PAD, which is never a legal
//! generation. Aggregation implements the two decision rules the paper
//! uses: majority voting (Self-Consistency) and highest-reward (SART,
//! Best-of-N).

use crate::tokenizer::Token;
use crate::util::rng::Rng;

/// Temperature + top-k sampling over a logits row. `top_k == 0` disables
/// the top-k filter. PAD (token 0) is always masked.
pub fn sample_token(logits: &[f32], temp: f32, top_k: usize, rng: &mut Rng) -> Token {
    debug_assert!(!logits.is_empty());
    if temp <= 0.0 {
        return argmax_nonpad(logits);
    }
    let inv = 1.0 / temp;
    // Scaled logits with PAD masked.
    let mut scaled: Vec<(usize, f32)> = logits
        .iter()
        .enumerate()
        .skip(1) // PAD = 0
        .map(|(i, &l)| (i, l * inv))
        .collect();
    if top_k > 0 && top_k < scaled.len() {
        scaled.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scaled.truncate(top_k);
    }
    let max = scaled
        .iter()
        .map(|&(_, l)| l)
        .fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = scaled
        .iter()
        .map(|&(_, l)| ((l - max) as f64).exp())
        .collect();
    scaled[rng.weighted(&weights)].0 as Token
}

fn argmax_nonpad(logits: &[f32]) -> Token {
    let mut best = 1usize;
    for (i, &l) in logits.iter().enumerate().skip(1) {
        if l > logits[best] {
            best = i;
        }
    }
    best as Token
}

/// Majority vote over per-branch answers (None = no/invalid answer).
/// Ties break toward the answer that reached the count first, which is
/// also the earliest-completed branch — matching Self-Consistency's
/// behaviour under streaming completion.
pub fn majority_vote(answers: &[Option<u8>]) -> Option<u8> {
    let mut counts = [0usize; 10];
    let mut best: Option<u8> = None;
    let mut best_count = 0usize;
    for a in answers.iter().flatten() {
        let c = &mut counts[*a as usize];
        *c += 1;
        if *c > best_count {
            best_count = *c;
            best = Some(*a);
        }
    }
    best
}

/// Highest-reward completed answer (SART's final decision rule).
///
/// NaN rewards (a branch harvested before any PRM pass scored it) are
/// skipped entirely: a NaN that entered `best` could never be displaced,
/// because every `r <= NaN` comparison is false, so one unscored first
/// entry would poison the vote. If no answer carries a real score, fall
/// back to majority voting over the answers rather than returning the
/// arbitrary NaN-first entry.
pub fn best_reward_vote(answers: &[(Option<u8>, f32)]) -> Option<u8> {
    let mut best: Option<(u8, f32)> = None;
    for (a, r) in answers {
        if let Some(a) = a {
            if r.is_nan() {
                continue;
            }
            match best {
                Some((_, br)) if *r <= br => {}
                _ => best = Some((*a, *r)),
            }
        }
    }
    match best {
        Some((a, _)) => Some(a),
        None => {
            let plain: Vec<Option<u8>> =
                answers.iter().map(|(a, _)| *a).collect();
            majority_vote(&plain)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits_with_peak(peak: usize, v: f32) -> Vec<f32> {
        let mut l = vec![0.0f32; 32];
        l[peak] = v;
        l
    }

    #[test]
    fn greedy_when_temp_zero() {
        let l = logits_with_peak(7, 3.0);
        let mut rng = Rng::new(0);
        for _ in 0..10 {
            assert_eq!(sample_token(&l, 0.0, 0, &mut rng), 7);
        }
    }

    #[test]
    fn never_samples_pad() {
        // PAD has a huge logit but must be masked.
        let mut l = vec![-5.0f32; 32];
        l[0] = 100.0;
        l[3] = 1.0;
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            assert_ne!(sample_token(&l, 1.0, 0, &mut rng), 0);
        }
    }

    #[test]
    fn temperature_sharpens() {
        let mut l = vec![0.0f32; 8];
        l[2] = 2.0;
        l[5] = 1.5;
        let mut rng = Rng::new(2);
        let mut count_hot = |temp: f32, rng: &mut Rng| {
            (0..2000)
                .filter(|_| sample_token(&l, temp, 0, rng) == 2)
                .count()
        };
        let cold = count_hot(0.2, &mut rng);
        let hot = count_hot(2.0, &mut rng);
        assert!(cold > hot, "cold={cold} hot={hot}");
    }

    #[test]
    fn top_k_filters() {
        let mut l = vec![0.0f32; 8];
        l[2] = 3.0;
        l[5] = 2.0;
        l[6] = 1.0;
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            let t = sample_token(&l, 5.0, 2, &mut rng);
            assert!(t == 2 || t == 5, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let l: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..50 {
            assert_eq!(
                sample_token(&l, 0.9, 0, &mut a),
                sample_token(&l, 0.9, 0, &mut b)
            );
        }
    }

    #[test]
    fn majority_vote_basic() {
        assert_eq!(
            majority_vote(&[Some(3), Some(3), Some(7), None]),
            Some(3)
        );
        assert_eq!(majority_vote(&[None, None]), None);
        // First-to-count tie-break.
        assert_eq!(majority_vote(&[Some(1), Some(2)]), Some(1));
    }

    #[test]
    fn best_reward_picks_max() {
        let v = [(Some(4u8), 0.2f32), (Some(9), 0.8), (None, 0.99)];
        assert_eq!(best_reward_vote(&v), Some(9));
        assert_eq!(best_reward_vote(&[(None, 1.0)]), None);
    }

    #[test]
    fn best_reward_skips_nan_first_entry() {
        // A NaN first entry must not win by being undisplaceable
        // (`r <= NaN` is false for every r).
        let v = [(Some(7u8), f32::NAN), (Some(3), 0.4), (Some(5), 0.9)];
        assert_eq!(best_reward_vote(&v), Some(5));
        // NaN anywhere is ignored, not just at the front.
        let v = [(Some(3u8), 0.4), (Some(7), f32::NAN), (Some(5), 0.2)];
        assert_eq!(best_reward_vote(&v), Some(3));
    }

    #[test]
    fn best_reward_all_nan_falls_back_to_majority() {
        let v = [
            (Some(2u8), f32::NAN),
            (Some(8), f32::NAN),
            (Some(8), f32::NAN),
            (None, 0.9),
        ];
        assert_eq!(best_reward_vote(&v), Some(8));
        // No answers at all → None even with the fallback.
        assert_eq!(
            best_reward_vote(&[(None, f32::NAN), (None, 0.5)]),
            None
        );
    }
}
