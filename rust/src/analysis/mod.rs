//! Order-statistics analysis behind redundant sampling (paper Lemma 1).
//!
//! Let response length X have CDF `F_X`. With N parallel branches and
//! early stopping after the M-th completion, the decoding steps needed is
//! the M-th order statistic `X_(M)`, whose CDF is
//!
//! ```text
//! F_{X_(M)}(x; N) = Σ_{i=M}^{N}  C(N, i) · F(x)^i · (1 − F(x))^{N−i}
//! ```
//!
//! which is *increasing in N* for fixed M — sampling more branches makes
//! M completions arrive sooner. This module evaluates the formula, checks
//! the monotonicity claim (property-tested in `rust/tests/properties.rs`),
//! and runs the Monte-Carlo verification printed by
//! `examples/paper_figures --lemma1`.

use crate::util::rng::Rng;

/// log(C(n, k)) via lgamma-free accumulation (exact enough for n ≤ 1e4).
fn ln_choose(n: u64, k: u64) -> f64 {
    debug_assert!(k <= n);
    let k = k.min(n - k);
    let mut acc = 0.0f64;
    for i in 0..k {
        acc += ((n - i) as f64).ln() - ((i + 1) as f64).ln();
    }
    acc
}

/// Binomial tail: P(Bin(n, p) >= m).
pub fn binomial_tail(n: u64, m: u64, p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "p out of range");
    if m == 0 {
        return 1.0;
    }
    if m > n {
        return 0.0;
    }
    if p <= 0.0 {
        return 0.0;
    }
    if p >= 1.0 {
        return 1.0;
    }
    let mut total = 0.0;
    for i in m..=n {
        let ln_term = ln_choose(n, i)
            + i as f64 * p.ln()
            + (n - i) as f64 * (1.0 - p).ln();
        total += ln_term.exp();
    }
    total.min(1.0)
}

/// Lemma 1: CDF of the M-th order statistic at a point where the base CDF
/// equals `f_x`.
pub fn order_statistic_cdf(f_x: f64, m: u64, n: u64) -> f64 {
    binomial_tail(n, m, f_x)
}

/// Expected decoding steps to collect M completions out of N branches,
/// where per-branch length is sampled by `sampler`. Monte-Carlo.
pub fn expected_mth_completion<F: FnMut(&mut Rng) -> f64>(
    mut sampler: F,
    m: usize,
    n: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    assert!(m >= 1 && m <= n && trials > 0);
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut lens = Vec::with_capacity(n);
    for _ in 0..trials {
        lens.clear();
        for _ in 0..n {
            lens.push(sampler(&mut rng));
        }
        lens.sort_by(|a, b| a.partial_cmp(b).unwrap());
        total += lens[m - 1];
    }
    total / trials as f64
}

/// Empirical CDF of the M-th order statistic at threshold `x`.
pub fn empirical_order_cdf<F: FnMut(&mut Rng) -> f64>(
    mut sampler: F,
    m: usize,
    n: usize,
    x: f64,
    trials: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut hits = 0usize;
    for _ in 0..trials {
        let mut count = 0usize;
        for _ in 0..n {
            if sampler(&mut rng) <= x {
                count += 1;
            }
        }
        if count >= m {
            hits += 1;
        }
    }
    hits as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_small_values() {
        assert!((ln_choose(5, 2).exp() - 10.0).abs() < 1e-9);
        assert!((ln_choose(10, 0).exp() - 1.0).abs() < 1e-12);
        assert!((ln_choose(52, 5).exp() - 2_598_960.0).abs() < 1e-3);
    }

    #[test]
    fn binomial_tail_edges() {
        assert_eq!(binomial_tail(10, 0, 0.3), 1.0);
        assert_eq!(binomial_tail(10, 11, 0.3), 0.0);
        assert_eq!(binomial_tail(10, 5, 0.0), 0.0);
        assert_eq!(binomial_tail(10, 5, 1.0), 1.0);
        // P(Bin(2, 0.5) >= 1) = 0.75.
        assert!((binomial_tail(2, 1, 0.5) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn lemma1_increasing_in_n() {
        // F_{X_(M)}(x; N) increases with N for fixed M and fixed F(x).
        for &f in &[0.1, 0.3, 0.5, 0.7] {
            for m in 1..=4u64 {
                let mut prev = 0.0;
                for n in m..=12 {
                    let cur = order_statistic_cdf(f, m, n);
                    assert!(
                        cur >= prev - 1e-12,
                        "not increasing: f={f} m={m} n={n}: {cur} < {prev}"
                    );
                    prev = cur;
                }
            }
        }
    }

    #[test]
    fn lemma1_matches_monte_carlo() {
        // Uniform(0,1) lengths: F(x) = x.
        let x = 0.4;
        let (m, n) = (2u64, 6u64);
        let analytic = order_statistic_cdf(x, m, n);
        let empirical = empirical_order_cdf(
            |rng| rng.f64(),
            m as usize,
            n as usize,
            x,
            200_000,
            42,
        );
        assert!(
            (analytic - empirical).abs() < 5e-3,
            "analytic {analytic} vs empirical {empirical}"
        );
    }

    #[test]
    fn more_branches_complete_sooner() {
        // E[X_(M); N] decreases in N — the operational content of
        // redundant sampling with early stopping.
        let heavy = |rng: &mut Rng| rng.lognormal(4.0, 0.8);
        let e4 = expected_mth_completion(heavy, 4, 4, 20_000, 7);
        let e6 = expected_mth_completion(heavy, 4, 6, 20_000, 7);
        let e8 = expected_mth_completion(heavy, 4, 8, 20_000, 7);
        assert!(e6 < e4, "{e6} !< {e4}");
        assert!(e8 < e6, "{e8} !< {e6}");
    }
}
