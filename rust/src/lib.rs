//! # SART — Serving LLM Reasoning Efficiently and Accurately
//!
//! Rust L3 coordinator of the three-layer reproduction of
//! *"Thinking Short and Right Over Thinking Long"* (2025). The paper's
//! contribution — **redundant sampling with early stopping** plus
//! **two-phase dynamic pruning** integrated with continuous batching
//! (Algorithm 1) — lives in [`coordinator`]; everything below it is the
//! serving substrate built from scratch for this repo:
//!
//! * [`runtime`] — PJRT client wrapper: loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them on
//!   device-resident buffers (Python is never on the request path).
//! * [`engine`] — the batched decode engine over fixed KV-cache slots,
//!   with an HLO-backed implementation and a virtual-time simulation twin
//!   used by tests and full-scale figure sweeps.
//! * [`kvcache`] — paged KV-cache accounting with prefix sharing,
//!   refcounts and a cross-request radix prefix cache (page-granular
//!   interning + LRU retention of released prompt pages); its token
//!   budget is what turns branch over-subscription into queuing delay,
//!   exactly the effect the paper studies.
//! * [`sampler`], [`tokenizer`] — host-side sampling (per-branch RNG) and
//!   the SynthMath token vocabulary mirrored from `python/compile/vocab.py`.
//! * [`prm`] — the process-reward-model client used by dynamic pruning.
//! * [`baselines`] — Vanilla, Self-Consistency and Rebase, each running on
//!   the same engine/batcher substrate for fair comparison.
//! * [`workload`], [`metrics`], [`server`] — request generation (Poisson
//!   arrivals over the synthetic datasets), percentile/accuracy/timeline
//!   metrics, and the serving front-end.
//! * [`cluster`] — R engine replicas behind a dispatch layer with
//!   pluggable load-balancing policies (round-robin, least-loaded, JSQ,
//!   power-of-two-choices, prefix-affinity), co-simulated in virtual
//!   time; `--replicas 1` reduces byte-identically to the single-engine
//!   path.
//! * [`frontend`] — the wall-clock serving runtime: a newline-delimited
//!   JSON TCP listener (`sart listen`) plus a trace-replay client
//!   (`sart replay`), pumping real arrivals through the same stepped
//!   scheduler core with virtual decode costs paced against the wall
//!   clock (`--time-scale`).
//! * [`analysis`] — the order-statistics machinery behind Lemma 1.
//! * [`util`], [`testkit`] — std-only JSON/npy/RNG/stats substrates and an
//!   in-repo property-testing helper (the offline registry has no
//!   proptest; see DESIGN.md §2).

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod frontend;
pub mod kvcache;
pub mod metrics;
pub mod prm;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod testkit;
pub mod tokenizer;
pub mod util;
pub mod workload;
