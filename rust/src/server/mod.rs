//! Serving front-end: builds the engine/PRM/scheduler stack from a
//! [`ServeSpec`] and runs a trace to a [`ServeReport`].
//!
//! This is the single entry point every binary uses (the `sart` CLI, the
//! examples, and the figure harnesses), guaranteeing that all experiments
//! exercise the same code path the server does.

use crate::baselines::{RebaseConfig, RebaseScheduler};
use crate::cluster::{
    serve_cluster, ClusterConfig, ClusterReport, REPLICA_SEED_STRIDE,
};
use crate::config::{EngineChoice, Method, PrmChoice, ServeSpec};
use crate::coordinator::{
    AdaptiveStats, ClockHandle, KvConfig, SchedConfig, Scheduler,
};
use crate::engine::hlo::{DecodeMode, HloEngine};
use crate::engine::sim::{SimCostModel, SimEngine};
use crate::engine::Engine;
use crate::metrics::{ServeReport, Timeline};
use crate::prm::{HloPrm, OraclePrm, PrmScorer};
use crate::runtime::{Manifest, Runtime};
use crate::util::clock::{RealClock, SimClock};
use crate::workload::{
    batch_trace, mixed_trace, poisson_trace, templated_trace, Request,
    TaskSpec,
};
use anyhow::{bail, Context, Result};

/// Everything produced by one serve run.
pub struct RunOutput {
    pub report: ServeReport,
    pub timeline: Timeline,
    pub outcomes: Vec<crate::coordinator::RequestOutcome>,
    /// Engine identity string (log/record provenance).
    pub engine_desc: String,
    /// Per-replica occupancy/skew aggregate — `Some` only for
    /// multi-replica (`--replicas > 1`) runs.
    pub cluster: Option<ClusterReport>,
    /// Σ prompt tokens covered by the cross-request prefix cache
    /// (cluster runs sum over replicas; 0 with the cache disabled).
    pub cache_hit_tokens: usize,
    /// Σ prompt tokens over all admitted requests.
    pub prompt_tokens: usize,
    /// Adaptive test-time-compute tallies (all zero with `--adaptive`
    /// off; cluster runs merge over replicas).
    pub adaptive: AdaptiveStats,
}

impl RunOutput {
    /// The machine-readable record of one run (`sart replay --json`,
    /// and anything else that wants to persist a run). Virtual and live
    /// serves write the same schema, so downstream tooling reads both.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut o = BTreeMap::new();
        o.insert("report".into(), self.report.to_json());
        o.insert(
            "timeline".into(),
            Json::Arr(
                self.timeline
                    .points
                    .iter()
                    .map(|p| {
                        let mut t = BTreeMap::new();
                        t.insert("t".into(), Json::Num(p.t));
                        t.insert(
                            "running_branches".into(),
                            Json::Num(p.running_branches as f64),
                        );
                        t.insert(
                            "running_tokens".into(),
                            Json::Num(p.running_tokens as f64),
                        );
                        t.insert(
                            "kv_pages_used".into(),
                            Json::Num(p.kv_pages_used as f64),
                        );
                        t.insert(
                            "queued_requests".into(),
                            Json::Num(p.queued_requests as f64),
                        );
                        Json::Obj(t)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "outcomes".into(),
            Json::Arr(
                self.outcomes
                    .iter()
                    .map(crate::frontend::proto::outcome_to_json)
                    .collect(),
            ),
        );
        o.insert("engine_desc".into(), Json::Str(self.engine_desc.clone()));
        o.insert(
            "cache_hit_tokens".into(),
            Json::Num(self.cache_hit_tokens as f64),
        );
        o.insert("prompt_tokens".into(), Json::Num(self.prompt_tokens as f64));
        let mut a = BTreeMap::new();
        a.insert(
            "fast_path_requests".into(),
            Json::Num(self.adaptive.fast_path_requests as f64),
        );
        a.insert(
            "spread_pruned_branches".into(),
            Json::Num(self.adaptive.spread_pruned_branches as f64),
        );
        a.insert(
            "cap_tightened_requests".into(),
            Json::Num(self.adaptive.cap_tightened_requests as f64),
        );
        a.insert(
            "static_fallbacks".into(),
            Json::Num(self.adaptive.static_fallbacks as f64),
        );
        o.insert("adaptive".into(), Json::Obj(a));
        Json::Obj(o)
    }
}

/// Generate the workload trace for a spec. A nonzero `--prefix-share`
/// selects the templated prefix-heavy generator (shared few-shot headers
/// + per-request questions); a nonzero `--hard-share` the mixed
/// easy/hard generator (`--dataset` as the easy side, `synth-gpqa` as
/// the hard side). At share 0 each degenerates to the plain
/// Poisson/batch trace, so the paths can never drift.
pub fn trace_for(spec: &ServeSpec) -> Result<Vec<Request>> {
    let task = TaskSpec::by_name(&spec.dataset)?;
    if spec.hard_share > 0.0 {
        return Ok(mixed_trace(
            &task,
            &TaskSpec::synth_gpqa(),
            spec.n_requests,
            spec.rate,
            spec.seed,
            spec.hard_share,
        ));
    }
    if spec.prefix_share > 0.0 {
        return Ok(templated_trace(
            &task,
            spec.n_requests,
            spec.rate,
            spec.seed,
            spec.prefix_share,
            spec.prefix_templates,
            spec.prefix_shots,
        ));
    }
    Ok(if spec.rate > 0.0 {
        poisson_trace(&task, spec.n_requests, spec.rate, spec.seed)
    } else {
        batch_trace(&task, spec.n_requests, spec.seed)
    })
}

/// Build the engine for a spec. HLO engines load `artifacts/` via the
/// `SART_ARTIFACTS` override or the default path.
pub fn build_engine(spec: &ServeSpec) -> Result<Box<dyn Engine>> {
    match &spec.engine {
        EngineChoice::Sim => {
            let task = TaskSpec::by_name(&spec.dataset)?;
            if spec.prefix_share > 0.0 {
                // Prefix-heavy prompts carry a few-shot header ahead of
                // the 27-token question. Size the advisory bucket (and
                // the sequence budget) to the worst-case header for this
                // dataset/shots combination: each shot is the 25-token
                // question + 4·hops derivation steps + 2 answer tokens.
                let shot_max = 28 + 4 * task.max_hops as usize;
                let bucket = spec.prefix_shots * shot_max + 27;
                let mut engine = SimEngine::new(
                    spec.slots,
                    bucket + 229,
                    task,
                    SimCostModel::default(),
                );
                engine.set_prompt_bucket(bucket);
                return Ok(Box::new(engine));
            }
            Ok(Box::new(SimEngine::new(
                spec.slots,
                256,
                task,
                SimCostModel::default(),
            )))
        }
        EngineChoice::Hlo { model, fused } => {
            if spec.prefix_share > 0.0 {
                bail!(
                    "--prefix-share requires --engine sim (headered prompts \
                     exceed the compiled HLO prompt bucket)"
                );
            }
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(crate::runtime::artifacts_dir())?;
            let mode = if *fused {
                DecodeMode::Fused
            } else {
                DecodeMode::Stepwise
            };
            let engine =
                HloEngine::load(rt, &manifest, model, spec.slots, mode,
                                spec.seed)
                    .with_context(|| format!("loading HLO engine `{model}`"))?;
            Ok(Box::new(engine))
        }
    }
}

/// Build the PRM scorer for a spec.
pub fn build_prm(spec: &ServeSpec) -> Result<Box<dyn PrmScorer>> {
    match &spec.prm {
        PrmChoice::Oracle { sigma } => {
            Ok(Box::new(OraclePrm::new(*sigma, spec.seed ^ 0x9137)))
        }
        PrmChoice::Hlo => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(crate::runtime::artifacts_dir())?;
            Ok(Box::new(HloPrm::load(rt, &manifest, spec.slots.min(16))?))
        }
    }
}

fn clock_for(spec: &ServeSpec) -> ClockHandle {
    match spec.engine {
        EngineChoice::Sim => ClockHandle::Sim(SimClock::new()),
        EngineChoice::Hlo { .. } => ClockHandle::Real(RealClock::new()),
    }
}

/// Run one full serving experiment.
pub fn run(spec: &ServeSpec) -> Result<RunOutput> {
    let trace = trace_for(spec)?;
    run_on_trace(spec, &trace)
}

/// Run a spec against an explicit trace (shared-workload comparisons).
pub fn run_on_trace(spec: &ServeSpec, trace: &[Request]) -> Result<RunOutput> {
    if spec.replicas > 1 {
        return run_cluster_on_trace(spec, trace);
    }
    let mut engine = build_engine(spec)?;
    let mut prm = build_prm(spec)?;
    let engine_desc = engine.describe();
    let label = spec.method.label();

    let (outcomes, timeline, cache_hit_tokens, prompt_tokens, adaptive) =
        match spec.method {
            Method::Rebase { n } => {
                if spec.adaptive.is_some() {
                    // Rebase has no branch-redundancy knobs for the policy
                    // to adapt; accepting the flag would silently serve a
                    // static baseline under an "adaptive" label.
                    bail!(
                        "--adaptive is not supported for the rebase \
                         baseline"
                    );
                }
            if spec.prefix_share > 0.0 {
                // Rebase prefills bare question prompts and ignores
                // Request headers; serving it a prefix-heavy trace would
                // silently compare it against methods paying for (and
                // caching) the full headered prompts.
                bail!(
                    "--prefix-share is not supported for the rebase \
                     baseline"
                );
            }
            if spec.prefill_chunk_tokens > 0 {
                // Rebase prefills bare prompts monolithically; serving it
                // with chunking silently off would skew any comparison
                // against the chunked schedulers.
                bail!(
                    "--prefill-chunk is not supported for the rebase \
                     baseline"
                );
            }
            let cfg = RebaseConfig {
                n_leaves: n,
                t_round: spec.t_round,
                temperature: spec.temperature,
                max_new: spec.max_new,
                reward_tau: 0.2,
                spawn_cap: 3 * n,
                kv_capacity_tokens: spec.kv_capacity_tokens,
                kv_page_tokens: spec.kv_page_tokens,
                seed: spec.seed,
            };
            let mut sched = RebaseScheduler::new(
                cfg,
                engine.as_mut(),
                prm.as_mut(),
                clock_for(spec),
            );
            let (outcomes, timeline) = sched.serve(trace)?;
            (outcomes, timeline, 0, 0, AdaptiveStats::default())
        }
        _ => {
            let mut sched = Scheduler::new(
                sched_cfg_for(spec)?,
                engine.as_mut(),
                prm.as_mut(),
                clock_for(spec),
            );
            let res = sched.serve(trace)?;
            (res.outcomes, res.timeline, res.cache_hit_tokens,
             res.prompt_tokens, res.adaptive)
        }
    };
    let report = ServeReport::from_outcomes(&label, &outcomes);
    Ok(RunOutput {
        report,
        timeline,
        outcomes,
        engine_desc,
        cluster: None,
        cache_hit_tokens,
        prompt_tokens,
        adaptive,
    })
}

/// The scheduler configuration a spec maps to — shared by the
/// single-engine, cluster, and live (`sart listen`) paths so none of
/// them can drift apart on a knob.
pub fn sched_cfg_for(spec: &ServeSpec) -> Result<SchedConfig> {
    let policy = spec
        .method
        .policy()
        .context("non-rebase method must map to a policy")?;
    Ok(SchedConfig {
        policy,
        t_round: spec.t_round,
        temperature: spec.temperature,
        max_new: spec.max_new,
        kv: KvConfig::new(spec.kv_capacity_tokens, spec.kv_page_tokens)
            .with_prefix_cache(spec.prefix_cache_pages)
            .with_chunked_prefill(
                spec.prefill_chunk_tokens,
                spec.max_batched_prefill_tokens,
            )
            .with_stream_admission(spec.kv_stream)
            .with_preemption(spec.kv_preempt),
        adaptive: spec.adaptive,
        seed: spec.seed,
    })
}

/// Multi-replica serve: R independent engine/PRM/scheduler stacks behind
/// the `cluster` dispatch layer (virtual time only; see the module docs).
fn run_cluster_on_trace(
    spec: &ServeSpec,
    trace: &[Request],
) -> Result<RunOutput> {
    if matches!(spec.method, Method::Rebase { .. }) {
        bail!("--replicas > 1 is not supported for the rebase baseline");
    }
    if !matches!(spec.engine, EngineChoice::Sim) {
        bail!(
            "--replicas > 1 currently requires --engine sim (the cluster \
             layer co-simulates replicas in virtual time)"
        );
    }
    let sched = sched_cfg_for(spec)?;
    // Each replica gets its own engine + PRM, seeded off the base spec
    // with a per-replica stride (replica 0 keeps the base seed, matching
    // the R = 1 reduction the property tests pin down).
    let mut engines: Vec<Box<dyn Engine>> = Vec::with_capacity(spec.replicas);
    let mut prms: Vec<Box<dyn PrmScorer>> = Vec::with_capacity(spec.replicas);
    for i in 0..spec.replicas {
        let mut rspec = spec.clone();
        rspec.seed = spec.seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
        engines.push(build_engine(&rspec)?);
        prms.push(build_prm(&rspec)?);
    }
    let ccfg = ClusterConfig {
        replicas: spec.replicas,
        lb: spec.lb,
        sched,
        seed: spec.seed,
        audit: false,
        gossip_rounds: spec.gossip_rounds,
        gossip_adapt: spec.gossip_adapt,
        fault_plan: spec.fault_plan.clone(),
        scale: spec.scale,
    };
    let res = serve_cluster(&ccfg, &mut engines, &mut prms, trace)?;
    let label = format!(
        "{}@{}x{}",
        spec.method.label(),
        spec.replicas,
        spec.lb.label()
    );
    let report = ServeReport::from_outcomes(&label, &res.outcomes);
    let timeline = res.merged_timeline();
    let cache_hit_tokens =
        res.replica_results.iter().map(|r| r.cache_hit_tokens).sum();
    let prompt_tokens =
        res.replica_results.iter().map(|r| r.prompt_tokens).sum();
    let mut adaptive = AdaptiveStats::default();
    for r in &res.replica_results {
        adaptive.merge(r.adaptive.clone());
    }
    let cluster = Some(res.report());
    Ok(RunOutput {
        report,
        timeline,
        outcomes: res.outcomes,
        engine_desc: format!(
            "cluster({} sim replicas, lb={})",
            spec.replicas,
            spec.lb.label()
        ),
        cluster,
        cache_hit_tokens,
        prompt_tokens,
        adaptive,
    })
}

/// Sample `n` independent full responses for one question directly through
/// an engine (no scheduler) — the probe used by the Fig. 2 length/quality
/// study and the quickstart.
pub fn sample_branches(
    engine: &mut dyn Engine,
    question: &crate::workload::Question,
    n: usize,
    temp: f32,
    seed: u64,
) -> Result<Vec<Vec<crate::tokenizer::Token>>> {
    use crate::engine::PrefillEntry;
    let slots = engine.caps().slots;
    let max_new = engine.caps().max_seq - engine.caps().prompt_len;
    let mut out = Vec::with_capacity(n);
    let mut next = 0usize;
    while next < n {
        let wave = (n - next).min(slots);
        let entries: Vec<PrefillEntry> = (0..wave)
            .map(|i| PrefillEntry {
                slot: i,
                prompt: question.prompt_tokens(),
                seed: seed ^ ((next + i) as u64).wrapping_mul(0x9E37),
                cached_tokens: 0,
            })
            .collect();
        engine.prefill(&entries)?;
        let mut done = vec![false; wave];
        let mut gens: Vec<Vec<crate::tokenizer::Token>> =
            vec![Vec::new(); wave];
        while !done.iter().all(|&d| d) {
            let active: Vec<usize> =
                (0..wave).filter(|&i| !done[i]).collect();
            let res = engine.decode(&active, 16, temp)?;
            for (slot, toks) in &res.emitted {
                gens[*slot].extend_from_slice(toks);
                if gens[*slot].last() == Some(&crate::tokenizer::EOS)
                    || gens[*slot].len() >= max_new
                {
                    done[*slot] = true;
                    engine.release(*slot);
                }
            }
        }
        out.extend(gens);
        next += wave;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Args;

    fn spec(extra: &str) -> ServeSpec {
        let args = Args::parse(
            format!("--requests 8 --rate 2 {extra}")
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        ServeSpec::from_args(&args).unwrap()
    }

    #[test]
    fn sim_run_all_methods() {
        for m in ["vanilla", "sc:4", "sart:4", "sart-noprune:4", "rebase:4"] {
            let mut s = spec(&format!("--method {m}"));
            s.kv_capacity_tokens = 8192;
            let out = run(&s).unwrap_or_else(|e| panic!("{m}: {e}"));
            assert_eq!(out.report.n_requests, 8, "{m}");
        }
    }

    #[test]
    fn adaptive_mixed_serve_end_to_end() {
        // --adaptive + --hard-share plumb through spec → trace → scheduler
        // and every request still finishes, single-engine and clustered.
        let mut s = spec(
            "--method sart:4 --adaptive --adaptive-min-samples 2 \
             --hard-share 0.5",
        );
        s.kv_capacity_tokens = 8192;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        let json = out.to_json().to_string();
        assert!(json.contains("fast_path_requests"));
        let mut c = s.clone();
        c.replicas = 2;
        let out = run(&c).unwrap();
        assert_eq!(out.report.n_requests, 8);
        // Rebase has nothing for the policy to adapt.
        let s = spec("--method rebase:4 --adaptive");
        assert!(run(&s).is_err(), "rebase must reject --adaptive");
    }

    #[test]
    fn cluster_run_serves_all_and_reports_skew() {
        for lb in ["rr", "least-loaded", "jsq", "p2c"] {
            let mut s =
                spec(&format!("--method sart:4 --replicas 3 --lb {lb}"));
            s.kv_capacity_tokens = 8192;
            let out = run(&s).unwrap_or_else(|e| panic!("{lb}: {e}"));
            assert_eq!(out.report.n_requests, 8, "{lb}");
            let c = out.cluster.as_ref().expect("cluster report");
            assert_eq!(c.replicas, 3);
            assert_eq!(
                c.per_replica_requests.iter().sum::<usize>(),
                8,
                "{lb}"
            );
            assert!(c.request_skew >= 1.0 && c.occupancy_skew >= 1.0);
        }
    }

    #[test]
    fn prefix_share_serve_hits_cache_end_to_end() {
        let mut s = spec(
            "--method sart:4 --prefix-share 1.0 --prefix-templates 1 \
             --prefix-cache 64",
        );
        s.kv_capacity_tokens = 32768;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        assert!(out.prompt_tokens > 0);
        assert!(
            out.cache_hit_tokens > 0,
            "shared-template serve produced no cache hits"
        );
        // Cache off: same workload, zero hits.
        let mut cold = s.clone();
        cold.prefix_cache_pages = 0;
        let out_cold = run(&cold).unwrap();
        assert_eq!(out_cold.cache_hit_tokens, 0);
        assert_eq!(out_cold.report.n_requests, 8);
        // HLO engines reject prefix-heavy workloads up front.
        let mut hlo = s.clone();
        hlo.engine = EngineChoice::Hlo {
            model: "r1mini-tiny".into(),
            fused: true,
        };
        assert!(run(&hlo).is_err());
    }

    #[test]
    fn prefix_affinity_cluster_serves_all() {
        let mut s = spec(
            "--method sart:4 --replicas 3 --lb prefix-affinity \
             --prefix-share 0.9 --prefix-templates 3 --prefix-cache 64",
        );
        s.kv_capacity_tokens = 32768;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        let c = out.cluster.as_ref().expect("cluster report");
        assert_eq!(c.replicas, 3);
        assert_eq!(c.per_replica_requests.iter().sum::<usize>(), 8);
        assert!((0.0..=1.0).contains(&c.cache_hit_rate));
    }

    #[test]
    fn gossip_affinity_cluster_serves_all() {
        // End-to-end --gossip-rounds plumbing: spec → ClusterConfig →
        // digest-table routing, with the probe counter pinned at zero.
        let mut s = spec(
            "--method sart:4 --replicas 3 --lb prefix-affinity \
             --gossip-rounds 4 --prefix-share 0.9 --prefix-templates 3 \
             --prefix-cache 64",
        );
        s.kv_capacity_tokens = 32768;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        let c = out.cluster.as_ref().expect("cluster report");
        assert_eq!(c.gossip.gossip_rounds, 4);
        assert_eq!(c.gossip.probe_calls, 0, "gossip routing must not probe");
        // The probe-mode twin pays R probes per arrival and never
        // touches the table.
        let mut probe = s.clone();
        probe.gossip_rounds = 0;
        let out = run(&probe).unwrap();
        let c = out.cluster.as_ref().expect("cluster report");
        assert_eq!(c.gossip.probe_calls, 3 * 8);
        assert_eq!(c.gossip.advertisements, 0);
    }

    #[test]
    fn faulted_cluster_serve_completes_all() {
        // End-to-end --fault-plan plumbing: spec → ClusterConfig → the
        // failure/restart pump, with every request still answered.
        let mut s = spec(
            "--method sart:4 --replicas 3 --lb p2c \
             --fault-plan fail@1.0:1,restart@3.0:1",
        );
        s.kv_capacity_tokens = 8192;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        let c = out.cluster.as_ref().expect("cluster report");
        assert_eq!(c.fault.failures, 1);
        assert_eq!(c.fault.restarts, 1);
    }

    #[test]
    fn scaled_cluster_serve_completes_all() {
        // Scale controller plumbing: start at 1 live replica of 3 and
        // let queue pressure activate standbys. A batch trace (all
        // arrivals at t = 0) piles the queue up deterministically.
        let mut s = spec(
            "--method sart:4 --replicas 3 --lb jsq --rate 0 \
             --scale-min 1 --scale-up-queue 1 --scale-cooldown 1",
        );
        s.kv_capacity_tokens = 8192;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        let c = out.cluster.as_ref().expect("cluster report");
        assert!(c.fault.scale_ups >= 1, "pressure never scaled up");
    }

    #[test]
    fn cluster_rejects_unsupported_combos() {
        let s = spec("--method rebase:4 --replicas 2");
        assert!(run(&s).is_err(), "rebase cluster must be rejected");
        let s = spec("--method rebase:4 --prefill-chunk 16");
        assert!(run(&s).is_err(), "rebase has no chunked-prefill path");
    }

    #[test]
    fn chunked_prefill_serve_end_to_end() {
        // Prefix-heavy workload, cold caches, streaming prefill: every
        // request must still finish, the timeline must show a prefill
        // backlog at some point, and the TTFT split must be ordered.
        let mut s = spec(
            "--method sart:4 --prefix-share 1.0 --prefix-templates 4 \
             --prefix-shots 4 --prefill-chunk 24 --prefill-budget 48 \
             --rate 4",
        );
        s.kv_capacity_tokens = 32768;
        let out = run(&s).unwrap();
        assert_eq!(out.report.n_requests, 8);
        assert!(
            out.timeline
                .points
                .iter()
                .any(|p| p.queued_prefill_tokens > 0),
            "long cold headers never queued any prefill"
        );
        let last = out.timeline.points.last().unwrap();
        assert_eq!(last.queued_prefill_tokens, 0, "drained serve");
        assert!(last.prefill_seconds > 0.0);
        for o in &out.outcomes {
            assert!(o.prefill_done_at >= o.admitted_at);
            assert!(o.finished_at >= o.prefill_done_at);
            assert!(o.prefill_latency() >= 0.0 && o.ttft() >= 0.0);
        }
    }

    #[test]
    fn shared_trace_comparison_is_fair() {
        let s1 = spec("--method sc:4");
        let trace = trace_for(&s1).unwrap();
        let out1 = run_on_trace(&s1, &trace).unwrap();
        let s2 = spec("--method sart:4");
        let out2 = run_on_trace(&s2, &trace).unwrap();
        // Same workload: same request count, same arrival times.
        assert_eq!(out1.report.n_requests, out2.report.n_requests);
        assert_eq!(
            out1.outcomes.last().unwrap().arrival,
            out2.outcomes.last().unwrap().arrival
        );
    }
}
