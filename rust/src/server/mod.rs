//! Serving front-end: builds the engine/PRM/scheduler stack from a
//! [`ServeSpec`] and runs a trace to a [`ServeReport`].
//!
//! This is the single entry point every binary uses (the `sart` CLI, the
//! examples, and the figure harnesses), guaranteeing that all experiments
//! exercise the same code path the server does.

use crate::baselines::{RebaseConfig, RebaseScheduler};
use crate::config::{EngineChoice, Method, PrmChoice, ServeSpec};
use crate::coordinator::{ClockHandle, SchedConfig, Scheduler};
use crate::engine::hlo::{DecodeMode, HloEngine};
use crate::engine::sim::{SimCostModel, SimEngine};
use crate::engine::Engine;
use crate::metrics::{ServeReport, Timeline};
use crate::prm::{HloPrm, OraclePrm, PrmScorer};
use crate::runtime::{Manifest, Runtime};
use crate::util::clock::{RealClock, SimClock};
use crate::workload::{batch_trace, poisson_trace, Request, TaskSpec};
use anyhow::{Context, Result};

/// Everything produced by one serve run.
pub struct RunOutput {
    pub report: ServeReport,
    pub timeline: Timeline,
    pub outcomes: Vec<crate::coordinator::RequestOutcome>,
    /// Engine identity string (log/record provenance).
    pub engine_desc: String,
}

/// Generate the workload trace for a spec.
pub fn trace_for(spec: &ServeSpec) -> Result<Vec<Request>> {
    let task = TaskSpec::by_name(&spec.dataset)?;
    Ok(if spec.rate > 0.0 {
        poisson_trace(&task, spec.n_requests, spec.rate, spec.seed)
    } else {
        batch_trace(&task, spec.n_requests, spec.seed)
    })
}

/// Build the engine for a spec. HLO engines load `artifacts/` via the
/// `SART_ARTIFACTS` override or the default path.
pub fn build_engine(spec: &ServeSpec) -> Result<Box<dyn Engine>> {
    match &spec.engine {
        EngineChoice::Sim => {
            let task = TaskSpec::by_name(&spec.dataset)?;
            Ok(Box::new(SimEngine::new(
                spec.slots,
                256,
                task,
                SimCostModel::default(),
            )))
        }
        EngineChoice::Hlo { model, fused } => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(crate::runtime::artifacts_dir())?;
            let mode = if *fused {
                DecodeMode::Fused
            } else {
                DecodeMode::Stepwise
            };
            let engine =
                HloEngine::load(rt, &manifest, model, spec.slots, mode,
                                spec.seed)
                    .with_context(|| format!("loading HLO engine `{model}`"))?;
            Ok(Box::new(engine))
        }
    }
}

/// Build the PRM scorer for a spec.
pub fn build_prm(spec: &ServeSpec) -> Result<Box<dyn PrmScorer>> {
    match &spec.prm {
        PrmChoice::Oracle { sigma } => {
            Ok(Box::new(OraclePrm::new(*sigma, spec.seed ^ 0x9137)))
        }
        PrmChoice::Hlo => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(crate::runtime::artifacts_dir())?;
            Ok(Box::new(HloPrm::load(rt, &manifest, spec.slots.min(16))?))
        }
    }
}

fn clock_for(spec: &ServeSpec) -> ClockHandle {
    match spec.engine {
        EngineChoice::Sim => ClockHandle::Sim(SimClock::new()),
        EngineChoice::Hlo { .. } => ClockHandle::Real(RealClock::new()),
    }
}

/// Run one full serving experiment.
pub fn run(spec: &ServeSpec) -> Result<RunOutput> {
    let trace = trace_for(spec)?;
    run_on_trace(spec, &trace)
}

/// Run a spec against an explicit trace (shared-workload comparisons).
pub fn run_on_trace(spec: &ServeSpec, trace: &[Request]) -> Result<RunOutput> {
    let mut engine = build_engine(spec)?;
    let mut prm = build_prm(spec)?;
    let engine_desc = engine.describe();
    let label = spec.method.label();

    let (outcomes, timeline) = match spec.method {
        Method::Rebase { n } => {
            let cfg = RebaseConfig {
                n_leaves: n,
                t_round: spec.t_round,
                temperature: spec.temperature,
                max_new: spec.max_new,
                reward_tau: 0.2,
                spawn_cap: 3 * n,
                kv_capacity_tokens: spec.kv_capacity_tokens,
                kv_page_tokens: spec.kv_page_tokens,
                seed: spec.seed,
            };
            let mut sched = RebaseScheduler::new(
                cfg,
                engine.as_mut(),
                prm.as_mut(),
                clock_for(spec),
            );
            sched.serve(trace)?
        }
        _ => {
            let policy = spec
                .method
                .policy()
                .context("non-rebase method must map to a policy")?;
            let cfg = SchedConfig {
                policy,
                t_round: spec.t_round,
                temperature: spec.temperature,
                max_new: spec.max_new,
                kv_capacity_tokens: spec.kv_capacity_tokens,
                kv_page_tokens: spec.kv_page_tokens,
                seed: spec.seed,
            };
            let mut sched = Scheduler::new(
                cfg,
                engine.as_mut(),
                prm.as_mut(),
                clock_for(spec),
            );
            let res = sched.serve(trace)?;
            (res.outcomes, res.timeline)
        }
    };
    let report = ServeReport::from_outcomes(&label, &outcomes);
    Ok(RunOutput { report, timeline, outcomes, engine_desc })
}

/// Sample `n` independent full responses for one question directly through
/// an engine (no scheduler) — the probe used by the Fig. 2 length/quality
/// study and the quickstart.
pub fn sample_branches(
    engine: &mut dyn Engine,
    question: &crate::workload::Question,
    n: usize,
    temp: f32,
    seed: u64,
) -> Result<Vec<Vec<crate::tokenizer::Token>>> {
    use crate::engine::PrefillEntry;
    let slots = engine.caps().slots;
    let max_new = engine.caps().max_seq - engine.caps().prompt_len;
    let mut out = Vec::with_capacity(n);
    let mut next = 0usize;
    while next < n {
        let wave = (n - next).min(slots);
        let entries: Vec<PrefillEntry> = (0..wave)
            .map(|i| PrefillEntry {
                slot: i,
                prompt: question.prompt_tokens(),
                seed: seed ^ ((next + i) as u64).wrapping_mul(0x9E37),
            })
            .collect();
        engine.prefill(&entries)?;
        let mut done = vec![false; wave];
        let mut gens: Vec<Vec<crate::tokenizer::Token>> =
            vec![Vec::new(); wave];
        while !done.iter().all(|&d| d) {
            let active: Vec<usize> =
                (0..wave).filter(|&i| !done[i]).collect();
            let res = engine.decode(&active, 16, temp)?;
            for (slot, toks) in &res.emitted {
                gens[*slot].extend_from_slice(toks);
                if gens[*slot].last() == Some(&crate::tokenizer::EOS)
                    || gens[*slot].len() >= max_new
                {
                    done[*slot] = true;
                    engine.release(*slot);
                }
            }
        }
        out.extend(gens);
        next += wave;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Args;

    fn spec(extra: &str) -> ServeSpec {
        let args = Args::parse(
            format!("--requests 8 --rate 2 {extra}")
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        ServeSpec::from_args(&args).unwrap()
    }

    #[test]
    fn sim_run_all_methods() {
        for m in ["vanilla", "sc:4", "sart:4", "sart-noprune:4", "rebase:4"] {
            let mut s = spec(&format!("--method {m}"));
            s.kv_capacity_tokens = 8192;
            let out = run(&s).unwrap_or_else(|e| panic!("{m}: {e}"));
            assert_eq!(out.report.n_requests, 8, "{m}");
        }
    }

    #[test]
    fn shared_trace_comparison_is_fair() {
        let s1 = spec("--method sc:4");
        let trace = trace_for(&s1).unwrap();
        let out1 = run_on_trace(&s1, &trace).unwrap();
        let s2 = spec("--method sart:4");
        let out2 = run_on_trace(&s2, &trace).unwrap();
        // Same workload: same request count, same arrival times.
        assert_eq!(out1.report.n_requests, out2.report.n_requests);
        assert_eq!(
            out1.outcomes.last().unwrap().arrival,
            out2.outcomes.last().unwrap().arrival
        );
    }
}
