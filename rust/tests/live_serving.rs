//! Integration tests of the wall-clock front end: a loopback
//! `listen`/`replay` pair over real sockets.
//!
//! Time scales here are aggressive (hundreds of times faster than real
//! time) so a multi-minute virtual trace replays in well under a test
//! timeout; the assertions are about *protocol* properties — nothing
//! lost, everything finalized, shutdown refusing new work — not about
//! wall-clock latency values, which depend on machine load.

use sart::config::{Args, LiveConfig, ServeSpec};
use sart::frontend::{self, proto};
use sart::workload::Request;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn spec(extra: &str) -> ServeSpec {
    let args = Args::parse(
        format!("--requests 8 --rate 2 {extra}")
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    let mut s = ServeSpec::from_args(&args).unwrap();
    s.kv_capacity_tokens = 8192;
    s
}

fn live(time_scale: f64, max_sessions: usize) -> LiveConfig {
    LiveConfig {
        addr: "127.0.0.1:0".into(),
        time_scale,
        max_sessions,
    }
}

#[test]
fn loopback_replay_serves_full_trace() {
    let s = spec("--method sart:4 --requests 64 --rate 8 --seed 7");
    let trace = sart::server::trace_for(&s).unwrap();
    assert_eq!(trace.len(), 64);
    let handle = frontend::listen(&s, &live(0.002, 256)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.002, true).unwrap();
    handle.join().unwrap();

    assert_eq!(res.requests_lost, 0, "accepted sessions must finalize");
    assert_eq!(res.rejected, 0, "trace never exceeds the session table");
    assert_eq!(res.outcomes.len(), 64);
    assert_eq!(res.wall_ttft.len(), 64);
    assert_eq!(res.wall_e2e.len(), 64);
    for (ttft, e2e) in res.wall_ttft.iter().zip(&res.wall_e2e) {
        assert!(*ttft >= 0.0 && *e2e >= *ttft, "wall times must order");
    }
    for o in &res.outcomes {
        assert!(o.finished_at >= o.admitted_at);
        assert!(o.branches_started > 0, "served request decoded nothing");
    }
    // Every outcome is a distinct session.
    let mut ids: Vec<usize> = res.outcomes.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 64, "duplicate session ids in outcomes");
}

#[test]
fn multi_replica_listener_serves_full_trace() {
    let s = spec("--method sart:4 --requests 24 --rate 8 --replicas 3");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.002, 256)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.002, true).unwrap();
    handle.join().unwrap();
    assert_eq!(res.requests_lost, 0);
    assert_eq!(res.outcomes.len(), 24);
}

#[test]
fn session_table_backpressure_rejects_not_hangs() {
    // One-session table + a burst of arrivals at t=0: everything past
    // the first in-flight session must be rejected with a retry hint,
    // never silently queued or dropped.
    let s = spec("--method sart:4 --requests 6 --rate 0");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.01, 1)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.01, true).unwrap();
    handle.join().unwrap();
    assert_eq!(res.requests_lost, 0);
    assert!(res.rejected > 0, "burst past a 1-session table must reject");
    assert_eq!(res.outcomes.len() + res.rejected, 6);
}

/// Raw-socket client helper: submit one request, read lines lazily.
struct RawSession {
    reader: BufReader<TcpStream>,
}

impl RawSession {
    fn submit(addr: &str, req: &Request) -> RawSession {
        let stream = TcpStream::connect(addr).unwrap();
        {
            let mut w = &stream;
            writeln!(
                w,
                "{}",
                proto::submit_line(&req.dataset, &req.question, &req.header)
            )
            .unwrap();
            w.flush().unwrap();
        }
        RawSession { reader: BufReader::new(stream) }
    }

    fn next_msg(&mut self) -> Option<proto::ServerMsg> {
        let mut line = String::new();
        if self.reader.read_line(&mut line).unwrap_or(0) == 0 {
            return None;
        }
        Some(proto::parse_server_line(line.trim()).unwrap())
    }
}

#[test]
fn graceful_shutdown_drains_inflight_and_refuses_new() {
    let s = spec("--method sart:4 --requests 6 --rate 0 --seed 3");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.005, 64)).unwrap();
    let addr = handle.addr().to_string();

    // Open six sessions and wait for each `accepted` line — once read,
    // the session is in the core's table and shutdown must drain it.
    let mut sessions: Vec<RawSession> = trace
        .iter()
        .map(|r| RawSession::submit(&addr, r))
        .collect();
    for sess in &mut sessions {
        match sess.next_msg().expect("accepted line") {
            proto::ServerMsg::Accepted { .. } => {}
            other => panic!("expected accepted, got {other:?}"),
        }
    }

    // Shutdown mid-trace. The ack is written only after the shutdown
    // message is on the control channel, so any submit opened after
    // reading it orders after the shutdown and must be refused.
    {
        let ctl = TcpStream::connect(&addr).unwrap();
        let mut w = &ctl;
        writeln!(w, "{}", proto::shutdown_line()).unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        BufReader::new(ctl).read_line(&mut line).unwrap();
        assert_eq!(
            proto::parse_server_line(line.trim()).unwrap(),
            proto::ServerMsg::ShutdownAck
        );
    }

    // New sessions are refused with a clean error line.
    let mut late = RawSession::submit(&addr, &trace[0]);
    match late.next_msg().expect("refusal line") {
        proto::ServerMsg::Refused { error } => {
            assert!(error.contains("shutting down"), "error: {error}");
        }
        other => panic!("expected refused, got {other:?}"),
    }
    drop(late);

    // Every accepted session still drains to its `finalized` event.
    for (i, sess) in sessions.iter_mut().enumerate() {
        let mut finalized = false;
        while let Some(msg) = sess.next_msg() {
            if let proto::ServerMsg::Finalized { outcome, .. } = msg {
                assert!(outcome.finished_at >= outcome.admitted_at);
                finalized = true;
                break;
            }
        }
        assert!(finalized, "session {i} never saw finalized");
        // Server closes the connection after finalized.
        assert!(sess.next_msg().is_none(), "data after finalized");
    }
    drop(sessions);

    handle.join().unwrap();
}
