//! Integration tests of the wall-clock front end: a loopback
//! `listen`/`replay` pair over real sockets.
//!
//! Time scales here are aggressive (hundreds of times faster than real
//! time) so a multi-minute virtual trace replays in well under a test
//! timeout; the assertions are about *protocol* properties — nothing
//! lost, everything finalized, shutdown refusing new work — not about
//! wall-clock latency values, which depend on machine load.

use sart::config::{Args, ListenerTuning, LiveConfig, ServeSpec};
use sart::frontend::{self, proto};
use sart::workload::Request;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

fn spec(extra: &str) -> ServeSpec {
    let args = Args::parse(
        format!("--requests 8 --rate 2 {extra}")
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    let mut s = ServeSpec::from_args(&args).unwrap();
    s.kv_capacity_tokens = 8192;
    s
}

fn live(time_scale: f64, max_sessions: usize) -> LiveConfig {
    LiveConfig {
        addr: "127.0.0.1:0".into(),
        time_scale,
        max_sessions,
    }
}

#[test]
fn loopback_replay_serves_full_trace() {
    let s = spec("--method sart:4 --requests 64 --rate 8 --seed 7");
    let trace = sart::server::trace_for(&s).unwrap();
    assert_eq!(trace.len(), 64);
    let handle = frontend::listen(&s, &live(0.002, 256)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.002, true).unwrap();
    handle.join().unwrap();

    assert_eq!(res.requests_lost, 0, "accepted sessions must finalize");
    assert_eq!(res.rejected, 0, "trace never exceeds the session table");
    assert_eq!(res.outcomes.len(), 64);
    assert_eq!(res.wall_ttft.len(), 64);
    assert_eq!(res.wall_e2e.len(), 64);
    for (ttft, e2e) in res.wall_ttft.iter().zip(&res.wall_e2e) {
        assert!(*ttft >= 0.0 && *e2e >= *ttft, "wall times must order");
    }
    for o in &res.outcomes {
        assert!(o.finished_at >= o.admitted_at);
        assert!(o.branches_started > 0, "served request decoded nothing");
    }
    // Every outcome is a distinct session.
    let mut ids: Vec<usize> = res.outcomes.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 64, "duplicate session ids in outcomes");
}

#[test]
fn multi_replica_listener_serves_full_trace() {
    let s = spec("--method sart:4 --requests 24 --rate 8 --replicas 3");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.002, 256)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.002, true).unwrap();
    handle.join().unwrap();
    assert_eq!(res.requests_lost, 0);
    assert_eq!(res.outcomes.len(), 24);
}

#[test]
fn session_table_backpressure_rejects_not_hangs() {
    // One-session table + a burst of arrivals at t=0: everything past
    // the first in-flight session must be rejected with a retry hint,
    // never silently queued or dropped.
    let s = spec("--method sart:4 --requests 6 --rate 0");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.01, 1)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.01, true).unwrap();
    handle.join().unwrap();
    assert_eq!(res.requests_lost, 0);
    assert!(res.rejected > 0, "burst past a 1-session table must reject");
    assert_eq!(res.outcomes.len() + res.rejected, 6);
}

/// Raw-socket client helper: submit one request, read lines lazily.
struct RawSession {
    reader: BufReader<TcpStream>,
}

impl RawSession {
    fn submit(addr: &str, req: &Request) -> RawSession {
        let stream = TcpStream::connect(addr).unwrap();
        {
            let mut w = &stream;
            writeln!(
                w,
                "{}",
                proto::submit_line(&req.dataset, &req.question, &req.header)
            )
            .unwrap();
            w.flush().unwrap();
        }
        RawSession { reader: BufReader::new(stream) }
    }

    fn next_msg(&mut self) -> Option<proto::ServerMsg> {
        let mut line = String::new();
        if self.reader.read_line(&mut line).unwrap_or(0) == 0 {
            return None;
        }
        Some(proto::parse_server_line(line.trim()).unwrap())
    }
}

#[test]
fn graceful_shutdown_drains_inflight_and_refuses_new() {
    let s = spec("--method sart:4 --requests 6 --rate 0 --seed 3");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.005, 64)).unwrap();
    let addr = handle.addr().to_string();

    // Open six sessions and wait for each `accepted` line — once read,
    // the session is in the core's table and shutdown must drain it.
    let mut sessions: Vec<RawSession> = trace
        .iter()
        .map(|r| RawSession::submit(&addr, r))
        .collect();
    for sess in &mut sessions {
        match sess.next_msg().expect("accepted line") {
            proto::ServerMsg::Accepted { .. } => {}
            other => panic!("expected accepted, got {other:?}"),
        }
    }

    // Shutdown mid-trace. The ack is written only after the shutdown
    // message is on the control channel, so any submit opened after
    // reading it orders after the shutdown and must be refused.
    {
        let ctl = TcpStream::connect(&addr).unwrap();
        let mut w = &ctl;
        writeln!(w, "{}", proto::shutdown_line()).unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        BufReader::new(ctl).read_line(&mut line).unwrap();
        assert_eq!(
            proto::parse_server_line(line.trim()).unwrap(),
            proto::ServerMsg::ShutdownAck
        );
    }

    // New sessions are refused with a clean error line.
    let mut late = RawSession::submit(&addr, &trace[0]);
    match late.next_msg().expect("refusal line") {
        proto::ServerMsg::Refused { error } => {
            assert!(error.contains("shutting down"), "error: {error}");
        }
        other => panic!("expected refused, got {other:?}"),
    }
    drop(late);

    // Every accepted session still drains to its `finalized` event.
    for (i, sess) in sessions.iter_mut().enumerate() {
        let mut finalized = false;
        while let Some(msg) = sess.next_msg() {
            if let proto::ServerMsg::Finalized { outcome, .. } = msg {
                assert!(outcome.finished_at >= outcome.admitted_at);
                finalized = true;
                break;
            }
        }
        assert!(finalized, "session {i} never saw finalized");
        // Server closes the connection after finalized.
        assert!(sess.next_msg().is_none(), "data after finalized");
    }
    drop(sessions);

    handle.join().unwrap();
}

/// Raw connection split into a write half and a line reader, for tests
/// that need to send arbitrary (including malformed) request lines.
fn raw_conn(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn send_line(stream: &mut TcpStream, line: &str) {
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
}

fn read_msg(reader: &mut BufReader<TcpStream>) -> Option<proto::ServerMsg> {
    let mut line = String::new();
    if reader.read_line(&mut line).unwrap_or(0) == 0 {
        return None;
    }
    Some(proto::parse_server_line(line.trim()).unwrap())
}

#[test]
fn protocol_abuse_is_answered_in_band_and_never_fatal() {
    let s = spec("--method sart:4 --requests 4 --rate 0 --seed 5");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.005, 64)).unwrap();
    let addr = handle.addr().to_string();

    // Four abusive lines on one connection: not JSON, an unknown op,
    // truncated JSON, and a line past the 64 KiB cap. Each must come
    // back as a structured `error` line — never a dropped socket.
    let (mut w, mut r) = raw_conn(&addr);
    send_line(&mut w, "this is not json");
    send_line(&mut w, "{\"op\":\"dance\"}");
    send_line(&mut w, "{\"op\":\"submit\",\"question\":");
    let huge =
        format!("{{\"op\":\"{}\"}}", "x".repeat(frontend::MAX_LINE_BYTES));
    send_line(&mut w, &huge);
    for i in 0..4 {
        match read_msg(&mut r) {
            Some(proto::ServerMsg::Error { error }) => {
                assert!(!error.is_empty(), "abuse line {i}: empty error");
            }
            other => panic!("abuse line {i}: expected error, got {other:?}"),
        }
    }

    // The abused connection still serves a full session.
    let req = &trace[0];
    send_line(
        &mut w,
        &proto::submit_line(&req.dataset, &req.question, &req.header),
    );
    match read_msg(&mut r).expect("accepted after abuse") {
        proto::ServerMsg::Accepted { .. } => {}
        other => panic!("expected accepted, got {other:?}"),
    }
    let mut finalized = false;
    while let Some(msg) = read_msg(&mut r) {
        if matches!(msg, proto::ServerMsg::Finalized { .. }) {
            finalized = true;
            break;
        }
    }
    assert!(finalized, "post-abuse session never finalized");

    // A client id already in flight on a *live* connection is an in-band
    // error on the second connection; the first session is untouched.
    let (mut w1, mut r1) = raw_conn(&addr);
    let t1 = &trace[1];
    send_line(
        &mut w1,
        &proto::submit_line_with(&t1.dataset, &t1.question, &t1.header, Some("dup")),
    );
    match read_msg(&mut r1).expect("accepted") {
        proto::ServerMsg::Accepted { client_id, .. } => {
            assert_eq!(client_id.as_deref(), Some("dup"));
        }
        other => panic!("expected accepted, got {other:?}"),
    }
    let (mut w2, mut r2) = raw_conn(&addr);
    let t2 = &trace[2];
    send_line(
        &mut w2,
        &proto::submit_line_with(&t2.dataset, &t2.question, &t2.header, Some("dup")),
    );
    match read_msg(&mut r2).expect("duplicate-id answer") {
        proto::ServerMsg::Error { error } => {
            assert!(error.contains("in flight"), "error: {error}");
        }
        other => panic!("expected error, got {other:?}"),
    }
    let mut finalized = false;
    while let Some(msg) = read_msg(&mut r1) {
        if matches!(msg, proto::ServerMsg::Finalized { .. }) {
            finalized = true;
            break;
        }
    }
    assert!(finalized, "first `dup` session must be unaffected");
    drop((w, r, w1, r1, w2, r2));

    handle.shutdown();
    handle.join().unwrap();
}

#[test]
fn mid_session_disconnect_reclaims_slot_and_counts_abort() {
    let s = spec("--method sart:4 --requests 2 --rate 0 --seed 11");
    let trace = sart::server::trace_for(&s).unwrap();
    // One-session table: the second submit only fits if the first —
    // whose client vanishes mid-stream — gets reaped, not leaked.
    let handle = frontend::listen(&s, &live(0.1, 1)).unwrap();
    let addr = handle.addr().to_string();

    let mut doomed = RawSession::submit(&addr, &trace[0]);
    match doomed.next_msg().expect("accepted") {
        proto::ServerMsg::Accepted { .. } => {}
        other => panic!("expected accepted, got {other:?}"),
    }
    drop(doomed); // socket dies mid-decode, no goodbye

    // The core notices the dead socket on its next event push, reclaims
    // the table slot, and counts the abort.
    let t0 = Instant::now();
    while handle.session_aborted() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "session_aborted never incremented after client disconnect"
        );
        thread::sleep(Duration::from_millis(10));
    }

    // The freed slot admits and serves a fresh session to completion.
    let mut next = RawSession::submit(&addr, &trace[1]);
    match next.next_msg().expect("accepted in reclaimed slot") {
        proto::ServerMsg::Accepted { .. } => {}
        other => panic!("expected accepted, got {other:?}"),
    }
    let mut finalized = false;
    while let Some(msg) = next.next_msg() {
        if matches!(msg, proto::ServerMsg::Finalized { .. }) {
            finalized = true;
            break;
        }
    }
    assert!(finalized, "reclaimed slot never served");

    handle.shutdown();
    handle.join().unwrap();
}

#[test]
fn replica_failure_migrates_sessions_without_closing_sockets() {
    // Two replicas, 16 sessions arriving in one burst, replica 1 killed
    // at virtual t = 0.75 — well inside the burst's service time. The
    // clients are legacy single-shot connections with no retry budget,
    // so zero lost sessions proves the migration happened *without*
    // closing any socket.
    let s = spec(
        "--method sart:4 --requests 16 --rate 0 --seed 13 --replicas 2 \
         --fault-plan fail@0.75:1",
    );
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.1, 64)).unwrap();
    let addr = handle.addr().to_string();
    let res = frontend::replay(&addr, &trace, 0.1, true).unwrap();
    handle.join().unwrap();

    assert_eq!(res.requests_lost, 0, "migration must not lose sessions");
    assert_eq!(res.rejected, 0);
    assert_eq!(res.outcomes.len(), 16);
    assert!(
        res.migrated_sessions >= 1,
        "failing a replica mid-burst must migrate at least one session"
    );
    // The client-side tally (migrated lines seen) and the server-side
    // outcome records (redispatch hops) must agree.
    let redispatched =
        res.outcomes.iter().filter(|o| o.redispatches > 0).count();
    assert_eq!(redispatched, res.migrated_sessions);
}

#[test]
fn pipelined_submits_multiplex_one_connection() {
    let s = spec("--method sart:4 --requests 3 --rate 0 --seed 17");
    let trace = sart::server::trace_for(&s).unwrap();
    let handle = frontend::listen(&s, &live(0.01, 64)).unwrap();
    let addr = handle.addr().to_string();

    // Three pipelined submits on one socket, correlated by client id.
    let (mut w, mut r) = raw_conn(&addr);
    for (i, req) in trace.iter().enumerate() {
        send_line(
            &mut w,
            &proto::submit_line_with(
                &req.dataset,
                &req.question,
                &req.header,
                Some(&format!("c{i}")),
            ),
        );
    }
    let mut accepted: HashMap<String, usize> = HashMap::new();
    let mut finalized: HashMap<usize, usize> = HashMap::new();
    while let Some(msg) = read_msg(&mut r) {
        match msg {
            proto::ServerMsg::Accepted { request, client_id } => {
                let cid = client_id.expect("accepted must echo client id");
                assert!(
                    accepted.insert(cid, request).is_none(),
                    "client id accepted twice"
                );
            }
            proto::ServerMsg::Finalized { request, .. } => {
                *finalized.entry(request).or_insert(0) += 1;
            }
            _ => {}
        }
    }
    // EOF only once every session on the connection finalized.
    assert_eq!(accepted.len(), 3);
    let mut ids: Vec<usize> = accepted.values().copied().collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 3, "sessions must get distinct request ids");
    for (cid, id) in &accepted {
        assert_eq!(
            finalized.get(id),
            Some(&1),
            "session {cid} (request {id}) must finalize exactly once"
        );
    }

    handle.shutdown();
    handle.join().unwrap();
}

#[test]
fn reconnect_and_resubmit_dedups_by_client_id() {
    let s = spec("--method sart:4 --requests 1 --rate 0 --seed 23");
    let trace = sart::server::trace_for(&s).unwrap();
    let req = &trace[0];
    // Slow enough (wall e2e well past the reconnect) that the session is
    // still in flight when the client comes back.
    let handle = frontend::listen(&s, &live(0.2, 8)).unwrap();
    let addr = handle.addr().to_string();

    let (mut w, mut r) = raw_conn(&addr);
    send_line(
        &mut w,
        &proto::submit_line_with(&req.dataset, &req.question, &req.header, Some("cid-0")),
    );
    let first_id = match read_msg(&mut r).expect("accepted") {
        proto::ServerMsg::Accepted { request, client_id } => {
            assert_eq!(client_id.as_deref(), Some("cid-0"));
            request
        }
        other => panic!("expected accepted, got {other:?}"),
    };
    drop((w, r)); // connection lost mid-stream

    // Reconnect and resubmit under the same client id: the server
    // reattaches to the in-flight session (same request id) instead of
    // dispatching the work twice. The old socket's death is noticed
    // asynchronously, so a transient duplicate-id error gets retried.
    let mut attempt = 0;
    let (reattached_id, mut r2) = loop {
        attempt += 1;
        assert!(attempt <= 50, "reattach never succeeded");
        let (mut w2, mut r2) = raw_conn(&addr);
        send_line(
            &mut w2,
            &proto::submit_line_with(
                &req.dataset,
                &req.question,
                &req.header,
                Some("cid-0"),
            ),
        );
        match read_msg(&mut r2).expect("reattach answer") {
            proto::ServerMsg::Accepted { request, client_id } => {
                assert_eq!(client_id.as_deref(), Some("cid-0"));
                break (request, r2);
            }
            proto::ServerMsg::Error { .. } => {
                thread::sleep(Duration::from_millis(20));
            }
            other => panic!("expected accepted or error, got {other:?}"),
        }
    };
    assert_eq!(reattached_id, first_id, "resubmit must dedup, not redo");
    let mut finals = 0;
    while let Some(msg) = read_msg(&mut r2) {
        if let proto::ServerMsg::Finalized { request, outcome, .. } = msg {
            assert_eq!(request, first_id);
            assert_eq!(outcome.id, first_id);
            finals += 1;
        }
    }
    assert_eq!(finals, 1, "exactly one finalized after reattach");

    // A resubmit after completion replays the retained record — the
    // work is not dispatched a second time.
    let (mut w3, mut r3) = raw_conn(&addr);
    send_line(
        &mut w3,
        &proto::submit_line_with(&req.dataset, &req.question, &req.header, Some("cid-0")),
    );
    match read_msg(&mut r3).expect("replayed accepted") {
        proto::ServerMsg::Accepted { request, .. } => {
            assert_eq!(request, first_id);
        }
        other => panic!("expected accepted, got {other:?}"),
    }
    let mut replayed = false;
    while let Some(msg) = read_msg(&mut r3) {
        if let proto::ServerMsg::Finalized { request, .. } = msg {
            assert_eq!(request, first_id);
            replayed = true;
        }
    }
    assert!(replayed, "retained finalized line must replay");

    handle.shutdown();
    handle.join().unwrap();
}

#[test]
fn slow_reader_sheds_tokens_but_never_terminal_lines() {
    let s = spec("--method sart:4 --requests 1 --rate 0 --seed 29");
    let trace = sart::server::trace_for(&s).unwrap();
    // A zero-depth session queue is the deterministic slow reader: every
    // `tokens` line sheds; control and terminal lines still land.
    let tuning =
        ListenerTuning { session_queue: 0, ..ListenerTuning::default() };
    let handle = frontend::listen_with(&s, &live(0.01, 8), &tuning).unwrap();
    let addr = handle.addr().to_string();

    let mut sess = RawSession::submit(&addr, &trace[0]);
    let mut saw_admitted = false;
    let mut saw_tokens = false;
    let mut fin = None;
    while let Some(msg) = sess.next_msg() {
        match msg {
            proto::ServerMsg::Admitted { .. } => saw_admitted = true,
            proto::ServerMsg::Tokens { .. } => saw_tokens = true,
            proto::ServerMsg::Finalized { shed, outcome, .. } => {
                fin = Some((shed, outcome));
            }
            _ => {}
        }
    }
    let (shed, outcome) = fin.expect("finalized despite shedding");
    assert!(saw_admitted, "admitted is a control line, never shed");
    assert!(!saw_tokens, "queue depth 0 must shed every tokens line");
    assert!(shed > 0, "finalized must report the shed count");
    assert!(outcome.tokens_generated > 0);

    handle.shutdown();
    handle.join().unwrap();
}
