//! Integration tests: Algorithm 1 over the virtual-time SimEngine.
//!
//! These validate the full coordinator behaviour — admission, continuous
//! batching, early stopping, two-phase pruning, finalization, metrics —
//! deterministically and without artifacts.

use sart::coordinator::{ClockHandle, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::metrics::ServeReport;
use sart::prm::{OraclePrm, PrmScorer};
use sart::util::clock::SimClock;
use sart::workload::{batch_trace, poisson_trace, TaskSpec};

fn sim_engine(slots: usize) -> SimEngine {
    SimEngine::new(slots, 256, TaskSpec::synth_gaokao(),
                   SimCostModel::default())
}

fn run(policy: Policy, n_requests: usize, rate: f64, slots: usize,
       kv_tokens: usize, seed: u64) -> sart::coordinator::ServeResult {
    let spec = TaskSpec::synth_gaokao();
    let trace = if rate > 0.0 {
        poisson_trace(&spec, n_requests, rate, seed)
    } else {
        batch_trace(&spec, n_requests, seed)
    };
    let mut engine = sim_engine(slots);
    let mut prm = OraclePrm::new(0.08, seed ^ 1);
    let cfg = SchedConfig {
        policy,
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv_capacity_tokens: kv_tokens,
        kv_page_tokens: 16,
        seed,
    };
    let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                   ClockHandle::Sim(SimClock::new()));
    sched.serve(&trace).expect("serve")
}

#[test]
fn vanilla_serves_all_requests() {
    let res = run(Policy::Vanilla, 20, 2.0, 4, 4096, 1);
    assert_eq!(res.outcomes.len(), 20);
    for o in &res.outcomes {
        assert!(o.finished_at >= o.arrival);
        assert!(o.branches_started == 1);
        assert!(o.e2e_latency() > 0.0);
    }
}

#[test]
fn self_consistency_completes_all_n() {
    let res = run(Policy::SelfConsistency { n: 4 }, 10, 1.0, 8, 8192, 2);
    for o in &res.outcomes {
        assert_eq!(o.branches_completed, 4, "SC waits for all N");
        assert_eq!(o.branches_pruned, 0);
        assert_eq!(o.response_lengths.len(), 4);
    }
}

#[test]
fn sart_early_stops_at_m() {
    let res = run(
        Policy::SartNoPrune { n: 8, m: 4 },
        10, 1.0, 16, 16384, 3,
    );
    for o in &res.outcomes {
        assert!(o.branches_completed >= 4, "needs at least M completions");
        // Early stopping: strictly fewer than N completions in the common
        // case; never more than N.
        assert!(o.branches_completed <= 8);
    }
    // At least one request should have stopped early (probability ~1).
    assert!(res.outcomes.iter().any(|o| o.branches_completed < 8));
}

#[test]
fn sart_prunes_under_tight_threshold() {
    let res = run(
        Policy::Sart { n: 8, m: 4, alpha: 0.6, beta: 4 },
        12, 1.0, 16, 16384, 4,
    );
    let pruned: usize = res.outcomes.iter().map(|o| o.branches_pruned).sum();
    assert!(pruned > 0, "a 0.6 exploration threshold must prune something");
    for o in &res.outcomes {
        assert!(o.branches_completed + o.branches_pruned <= 8);
    }
}

#[test]
fn sart_accuracy_reasonable() {
    // With the oracle PRM and branch sampling, SART should answer most
    // questions correctly (way above the 10% random-guess floor).
    let res = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                  40, 0.0, 16, 16384, 5);
    let report = ServeReport::from_outcomes("sart", &res.outcomes);
    assert!(report.accuracy > 0.5, "accuracy {}", report.accuracy);
}

#[test]
fn sart_beats_self_consistency_on_latency() {
    // The paper's headline: same-ish accuracy, much lower latency at the
    // same N under load.
    let sc = run(Policy::SelfConsistency { n: 8 }, 24, 2.0, 8, 6144, 6);
    let sart = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                   24, 2.0, 8, 6144, 6);
    let sc_rep = ServeReport::from_outcomes("sc", &sc.outcomes);
    let sart_rep = ServeReport::from_outcomes("sart", &sart.outcomes);
    assert!(
        sart_rep.e2e.p97 < sc_rep.e2e.p97,
        "sart p97 {} !< sc p97 {}",
        sart_rep.e2e.p97,
        sc_rep.e2e.p97
    );
}

#[test]
fn pruning_reduces_queue_latency() {
    // Fig. 6's mechanism: with a tight kv budget, pruning releases memory
    // and shortens the queue.
    let noprune = run(Policy::SartNoPrune { n: 8, m: 4 }, 24, 2.0, 8, 4096, 7);
    let prune = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                    24, 2.0, 8, 4096, 7);
    let q_np = ServeReport::from_outcomes("np", &noprune.outcomes).queue.mean;
    let q_p = ServeReport::from_outcomes("p", &prune.outcomes).queue.mean;
    assert!(q_p <= q_np, "pruning should not worsen queuing: {q_p} vs {q_np}");
}

#[test]
fn deterministic_across_runs() {
    let a = run(Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                8, 1.0, 8, 8192, 9);
    let b = run(Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                8, 1.0, 8, 8192, 9);
    assert_eq!(a.rounds, b.rounds);
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.answer, y.answer);
        assert_eq!(x.finished_at, y.finished_at);
        assert_eq!(x.tokens_generated, y.tokens_generated);
    }
}

#[test]
fn timeline_is_monotone_and_bounded() {
    let res = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                  16, 2.0, 8, 8192, 10);
    let mut last_t = 0.0;
    for p in &res.timeline.points {
        assert!(p.t >= last_t, "time went backwards");
        last_t = p.t;
        assert!(p.running_branches <= 8, "more branches than slots");
    }
    assert!(res.timeline.peak_branches() > 0);
}

#[test]
fn queuing_appears_under_overload() {
    // High arrival rate + tiny budget → queue delays must dominate.
    let res = run(Policy::SelfConsistency { n: 8 }, 16, 8.0, 4, 2048, 11);
    let rep = ServeReport::from_outcomes("sc", &res.outcomes);
    assert!(rep.queue.p90 > 0.1, "expected queuing, got {:?}", rep.queue);
}

#[test]
fn batch_arrival_all_finish() {
    let res = run(Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                  30, 0.0, 8, 4096, 12);
    assert_eq!(res.outcomes.len(), 30);
    let rep = ServeReport::from_outcomes("sart", &res.outcomes);
    assert!(rep.answered > 0.9, "answered {}", rep.answered);
}
