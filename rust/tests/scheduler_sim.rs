//! Integration tests: Algorithm 1 over the virtual-time SimEngine.
//!
//! These validate the full coordinator behaviour — admission, continuous
//! batching, early stopping, two-phase pruning, finalization, metrics —
//! deterministically and without artifacts.

use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::{
    ChunkResult, Engine, EngineCaps, PrefillEntry, ReplayEntry, SlotId,
};
use sart::metrics::ServeReport;
use sart::prm::{OraclePrm, PrmScorer};
use sart::tokenizer as tok;
use sart::util::clock::SimClock;
use sart::workload::{batch_trace, poisson_trace, TaskSpec};

fn sim_engine(slots: usize) -> SimEngine {
    SimEngine::new(slots, 256, TaskSpec::synth_gaokao(),
                   SimCostModel::default())
}

fn run(policy: Policy, n_requests: usize, rate: f64, slots: usize,
       kv_tokens: usize, seed: u64) -> sart::coordinator::ServeResult {
    let spec = TaskSpec::synth_gaokao();
    let trace = if rate > 0.0 {
        poisson_trace(&spec, n_requests, rate, seed)
    } else {
        batch_trace(&spec, n_requests, seed)
    };
    let mut engine = sim_engine(slots);
    let mut prm = OraclePrm::new(0.08, seed ^ 1);
    let cfg = SchedConfig {
        policy,
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(kv_tokens, 16),
        adaptive: None,
        seed,
    };
    let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                   ClockHandle::Sim(SimClock::new()));
    sched.serve(&trace).expect("serve")
}

#[test]
fn vanilla_serves_all_requests() {
    let res = run(Policy::Vanilla, 20, 2.0, 4, 4096, 1);
    assert_eq!(res.outcomes.len(), 20);
    for o in &res.outcomes {
        assert!(o.finished_at >= o.arrival);
        assert!(o.branches_started == 1);
        assert!(o.e2e_latency() > 0.0);
    }
}

#[test]
fn self_consistency_completes_all_n() {
    let res = run(Policy::SelfConsistency { n: 4 }, 10, 1.0, 8, 8192, 2);
    for o in &res.outcomes {
        // SC waits for all N branches to be harvested (branches_completed
        // counts only the answer-bearing subset).
        assert_eq!(o.response_lengths.len(), 4, "SC waits for all N");
        assert_eq!(o.branches_pruned, 0);
        assert!(o.branches_completed <= 4);
    }
}

#[test]
fn sart_early_stops_at_m() {
    let res = run(
        Policy::SartNoPrune { n: 8, m: 4 },
        10, 1.0, 16, 16384, 3,
    );
    for o in &res.outcomes {
        assert!(o.branches_completed >= 4, "needs at least M completions");
        // Early stopping: strictly fewer than N completions in the common
        // case; never more than N.
        assert!(o.branches_completed <= 8);
    }
    // At least one request should have stopped early (probability ~1).
    assert!(res.outcomes.iter().any(|o| o.branches_completed < 8));
}

#[test]
fn sart_prunes_under_tight_threshold() {
    let res = run(
        Policy::Sart { n: 8, m: 4, alpha: 0.6, beta: 4 },
        12, 1.0, 16, 16384, 4,
    );
    let pruned: usize = res.outcomes.iter().map(|o| o.branches_pruned).sum();
    assert!(pruned > 0, "a 0.6 exploration threshold must prune something");
    for o in &res.outcomes {
        assert!(o.branches_completed + o.branches_pruned <= 8);
    }
}

#[test]
fn sart_accuracy_reasonable() {
    // With the oracle PRM and branch sampling, SART should answer most
    // questions correctly (way above the 10% random-guess floor).
    let res = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                  40, 0.0, 16, 16384, 5);
    let report = ServeReport::from_outcomes("sart", &res.outcomes);
    assert!(report.accuracy > 0.5, "accuracy {}", report.accuracy);
}

#[test]
fn sart_beats_self_consistency_on_latency() {
    // The paper's headline: same-ish accuracy, much lower latency at the
    // same N under load.
    let sc = run(Policy::SelfConsistency { n: 8 }, 24, 2.0, 8, 6144, 6);
    let sart = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                   24, 2.0, 8, 6144, 6);
    let sc_rep = ServeReport::from_outcomes("sc", &sc.outcomes);
    let sart_rep = ServeReport::from_outcomes("sart", &sart.outcomes);
    assert!(
        sart_rep.e2e.p97 < sc_rep.e2e.p97,
        "sart p97 {} !< sc p97 {}",
        sart_rep.e2e.p97,
        sc_rep.e2e.p97
    );
}

#[test]
fn pruning_reduces_queue_latency() {
    // Fig. 6's mechanism: with a tight kv budget, pruning releases memory
    // and shortens the queue.
    let noprune = run(Policy::SartNoPrune { n: 8, m: 4 }, 24, 2.0, 8, 4096, 7);
    let prune = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                    24, 2.0, 8, 4096, 7);
    let q_np = ServeReport::from_outcomes("np", &noprune.outcomes).queue.mean;
    let q_p = ServeReport::from_outcomes("p", &prune.outcomes).queue.mean;
    assert!(q_p <= q_np, "pruning should not worsen queuing: {q_p} vs {q_np}");
}

#[test]
fn deterministic_across_runs() {
    let a = run(Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                8, 1.0, 8, 8192, 9);
    let b = run(Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                8, 1.0, 8, 8192, 9);
    assert_eq!(a.rounds, b.rounds);
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.answer, y.answer);
        assert_eq!(x.finished_at, y.finished_at);
        assert_eq!(x.tokens_generated, y.tokens_generated);
    }
}

#[test]
fn timeline_is_monotone_and_bounded() {
    let res = run(Policy::Sart { n: 8, m: 4, alpha: 0.5, beta: 4 },
                  16, 2.0, 8, 8192, 10);
    let mut last_t = 0.0;
    for p in &res.timeline.points {
        assert!(p.t >= last_t, "time went backwards");
        last_t = p.t;
        assert!(p.running_branches <= 8, "more branches than slots");
    }
    assert!(res.timeline.peak_branches() > 0);
}

#[test]
fn queuing_appears_under_overload() {
    // High arrival rate + tiny budget → queue delays must dominate.
    let res = run(Policy::SelfConsistency { n: 8 }, 16, 8.0, 4, 2048, 11);
    let rep = ServeReport::from_outcomes("sc", &res.outcomes);
    assert!(rep.queue.p90 > 0.1, "expected queuing, got {:?}", rep.queue);
}

#[test]
fn batch_arrival_all_finish() {
    let res = run(Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                  30, 0.0, 8, 4096, 12);
    assert_eq!(res.outcomes.len(), 30);
    let rep = ServeReport::from_outcomes("sart", &res.outcomes);
    assert!(rep.answered > 0.9, "answered {}", rep.answered);
}

#[test]
fn prefix_cache_saves_over_30pct_of_prefill_tokens() {
    // ISSUE 3 acceptance: on a prefix-heavy workload (every request
    // shares one few-shot template), the radix cache must cover > 30% of
    // all admitted prompt tokens. The shared header is ~120-144 tokens of
    // a ~150-170-token prompt, so every admission after the first hits
    // its full-page prefix (~0.7 expected).
    let spec = TaskSpec::synth_gaokao();
    let trace =
        sart::workload::templated_trace(&spec, 32, 2.0, 5, 1.0, 1, 3);
    let mut engine = SimEngine::new(8, 512, spec, SimCostModel::default());
    engine.set_prompt_bucket(256);
    let mut prm = OraclePrm::new(0.08, 5);
    let cfg = SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(32768, 16)
            .with_prefix_cache(64),
        adaptive: None,
        seed: 5,
    };
    let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                   ClockHandle::Sim(SimClock::new()));
    sched.set_audit(true);
    let res = sched.serve(&trace).expect("prefix serve");
    assert_eq!(res.outcomes.len(), 32);
    assert!(res.prompt_tokens > 0);
    let saved = res.cache_hit_tokens as f64 / res.prompt_tokens as f64;
    assert!(
        saved > 0.3,
        "prefill_tokens_saved_frac {saved:.3} ≤ 0.3 \
         ({} of {} prompt tokens)",
        res.cache_hit_tokens,
        res.prompt_tokens
    );
}

// ---------------------------------------------------------------------------
// Deterministic decision-rule regressions (scripted toy engine): the
// exploit-phase threshold under simultaneous completions and the
// answered-only early-stop quorum.
// ---------------------------------------------------------------------------

/// Engine that replays hand-written per-round token chunks, assigned to
/// branches in prefill order — lets a test pin exactly which branches
/// complete / cap in which round.
struct ChunkScriptEngine {
    caps: EngineCaps,
    /// Per branch (prefill order): the chunk emitted on each round.
    scripts: Vec<Vec<Vec<tok::Token>>>,
    next_script: usize,
    /// slot -> (script index, next round index).
    slots: Vec<Option<(usize, usize)>>,
}

impl ChunkScriptEngine {
    fn new(slots: usize, scripts: Vec<Vec<Vec<tok::Token>>>) -> Self {
        ChunkScriptEngine {
            caps: EngineCaps {
                slots,
                max_seq: 512,
                prompt_len: 64,
                chunk_t: 16,
            },
            scripts,
            next_script: 0,
            slots: vec![None; slots],
        }
    }
}

impl Engine for ChunkScriptEngine {
    fn caps(&self) -> EngineCaps {
        self.caps
    }

    fn prefill(&mut self, entries: &[PrefillEntry]) -> anyhow::Result<f64> {
        for e in entries {
            self.slots[e.slot] = Some((self.next_script, 0));
            self.next_script += 1;
        }
        Ok(0.01)
    }

    fn decode_into(
        &mut self,
        active: &[SlotId],
        _steps: usize,
        _temp: f32,
        out: &mut ChunkResult,
    ) -> anyhow::Result<()> {
        out.emitted.clear();
        out.cost = 0.05;
        for &slot in active {
            if let Some((si, ri)) = self.slots[slot] {
                if ri < self.scripts[si].len() {
                    out.emitted.push((slot, self.scripts[si][ri].clone()));
                    self.slots[slot] = Some((si, ri + 1));
                }
            }
        }
        Ok(())
    }

    fn replay(&mut self, _entries: &[ReplayEntry]) -> anyhow::Result<f64> {
        anyhow::bail!("replay unsupported in ChunkScriptEngine")
    }

    fn release(&mut self, slot: SlotId) {
        self.slots[slot] = None;
    }

    fn describe(&self) -> String {
        "chunk-script test engine".into()
    }
}

/// PRM keyed on the answered digit: `<ans> 1` → 0.3, `<ans> 2` → 0.9,
/// anything else (including still-running step chains) → 0.6.
struct AnswerKeyedPrm;

impl PrmScorer for AnswerKeyedPrm {
    fn score(&mut self, seqs: &[&[tok::Token]]) -> anyhow::Result<Vec<f32>> {
        Ok(seqs
            .iter()
            .map(|s| {
                let after_ans = s
                    .iter()
                    .position(|&t| t == tok::ANS)
                    .and_then(|i| s.get(i + 1))
                    .copied();
                match after_ans {
                    Some(t) if t == tok::digit(1) => 0.3,
                    Some(t) if t == tok::digit(2) => 0.9,
                    _ => 0.6,
                }
            })
            .collect())
    }

    fn describe(&self) -> String {
        "answer-keyed test prm".into()
    }
}

fn toy_cfg(policy: Policy, max_new: usize) -> SchedConfig {
    SchedConfig {
        policy,
        t_round: 16,
        temperature: 1.0,
        max_new,
        kv: KvConfig::new(4096, 16),
        adaptive: None,
        seed: 0,
    }
}

#[test]
fn exploit_threshold_is_max_over_simultaneous_completions() {
    // Round 1: branches 0 and 1 both complete (rewards 0.3 and 0.9);
    // branch 2 is mid-chain with reward 0.6. α′ must be max(0.3, 0.9) =
    // 0.9, which prunes branch 2 — the old branch-index-order threshold
    // (an arbitrary sibling's 0.3) would have let it decode on.
    let scripts = vec![
        vec![vec![tok::ETHINK, tok::ANS, tok::digit(1), tok::EOS]],
        vec![vec![tok::ETHINK, tok::ANS, tok::digit(2), tok::EOS]],
        vec![
            vec![tok::STEP; 16],
            vec![tok::STEP; 16],
            vec![tok::ETHINK, tok::ANS, tok::digit(4), tok::EOS],
        ],
    ];
    let mut engine = ChunkScriptEngine::new(4, scripts);
    let mut prm = AnswerKeyedPrm;
    let trace = batch_trace(&TaskSpec::synth_gaokao(), 1, 0);
    let mut sched = Scheduler::new(
        toy_cfg(Policy::Sart { n: 3, m: 3, alpha: 0.05, beta: 1 }, 64),
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.set_audit(true);
    let res = sched.serve(&trace).expect("serve");
    let o = &res.outcomes[0];
    assert_eq!(o.branches_pruned, 1, "0.6 < α′ = 0.9 must prune");
    assert_eq!(o.branches_completed, 2);
    assert_eq!(o.answer, Some(2), "vote must pick the 0.9-reward answer");
}

#[test]
fn capped_answerless_branches_do_not_satisfy_quorum() {
    // Branch 0 hits the generation cap (16 tokens, no EOS, no answer) in
    // round 2; branch 1 completes with an answer in round 5. With M = 1,
    // the capped junk response must NOT finalize the request — the
    // scheduler has to wait for the answered completion, while the capped
    // response stays available to the final vote.
    let scripts = vec![
        vec![vec![tok::STEP; 8], vec![tok::STEP; 8]],
        vec![
            vec![tok::STEP; 2],
            vec![tok::STEP; 2],
            vec![tok::STEP; 2],
            vec![tok::STEP; 2],
            vec![tok::ETHINK, tok::ANS, tok::digit(3), tok::EOS],
        ],
    ];
    let mut engine = ChunkScriptEngine::new(4, scripts);
    let mut prm = AnswerKeyedPrm;
    let trace = batch_trace(&TaskSpec::synth_gaokao(), 1, 0);
    let mut sched = Scheduler::new(
        toy_cfg(Policy::SartNoPrune { n: 2, m: 1 }, 16),
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.set_audit(true);
    let res = sched.serve(&trace).expect("serve");
    let o = &res.outcomes[0];
    assert_eq!(o.answer, Some(3), "must wait for the answered branch");
    assert_eq!(o.branches_completed, 1, "only answered harvests count");
    assert_eq!(
        o.response_lengths.len(),
        2,
        "capped response still recorded for the final vote"
    );
    assert_eq!(res.rounds, 5, "finalizes with the round-5 completion");
}
