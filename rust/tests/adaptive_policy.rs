//! Integration tests of the adaptive test-time-compute policy layer:
//! byte-identity with the layer off (the `--adaptive`-absent serve must
//! be today's serve), NaN/unscored-reward fallback, the fast-path
//! capped-vote regression, and mixed-workload determinism.

use sart::cluster::{serve_cluster, ClusterConfig, LbPolicy, REPLICA_SEED_STRIDE};
use sart::coordinator::{
    AdaptiveConfig, AdaptiveDecisionKind, ClockHandle, KvConfig, Policy,
    SchedConfig, Scheduler, ServeEvent, ServeResult,
};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::prm::{OraclePrm, PrmScorer};
use sart::prop_assert;
use sart::testkit::check;
use sart::tokenizer::Token;
use sart::util::clock::SimClock;
use sart::util::rng::Rng;
use sart::workload::{batch_trace, mixed_trace, poisson_trace, Request, TaskSpec};

fn random_policy(rng: &mut Rng) -> Policy {
    let n = 1 << rng.below(4); // 1,2,4,8
    match rng.below(4) {
        0 => Policy::Vanilla,
        1 => Policy::SelfConsistency { n },
        2 => Policy::SartNoPrune { n, m: (n / 2).max(1) },
        _ => Policy::Sart {
            n,
            m: (n / 2).max(1),
            alpha: (0.3 + 0.4 * rng.f64()) as f32,
            beta: (n / 2).max(1),
        },
    }
}

/// An armed adaptive config none of whose rules can ever fire: spreads
/// are >= 0 so a negative tolerance never concentrates, the huge
/// `min_samples` keeps the tail and fast-path rules unarmed, and any
/// tightened-cap candidate clamps to the static cap. A serve under this
/// config must schedule byte-identically to `adaptive: None` — the
/// decision hooks themselves must not perturb the static policy.
fn inert_cfg() -> AdaptiveConfig {
    AdaptiveConfig {
        spread_tol: -1.0,
        prune_keep: 1,
        tail_pct: 99.0,
        cap_slack: 1.0e9,
        min_samples: usize::MAX / 2,
        fast_reward: f32::INFINITY,
        fast_len: 1.0e12,
    }
}

struct Case {
    policy: Policy,
    slots: usize,
    t_round: usize,
    kv_tokens: usize,
    seed: u64,
    spec: TaskSpec,
    trace: Vec<Request>,
}

fn random_case(rng: &mut Rng) -> Case {
    let policy = random_policy(rng);
    let slots = 2 + rng.below(14);
    let n_req = 4 + rng.below(12);
    let rate = 0.5 + 4.0 * rng.f64();
    let spec = if rng.chance(0.5) {
        TaskSpec::synth_gaokao()
    } else {
        TaskSpec::synth_gpqa()
    };
    let seed = rng.next_u64();
    // Budget always admits at least one full request (no stalls).
    let min_pages = 2 + policy.n_branches() * 14 + 4;
    let kv_tokens = 16 * (min_pages + rng.below(1024));
    let trace = poisson_trace(&spec, n_req, rate, seed);
    Case {
        policy,
        slots,
        t_round: 8 + rng.below(24),
        kv_tokens,
        seed,
        spec,
        trace,
    }
}

impl Case {
    fn sched_cfg(&self, adaptive: Option<AdaptiveConfig>) -> SchedConfig {
        SchedConfig {
            policy: self.policy,
            t_round: self.t_round,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(self.kv_tokens, 16),
            adaptive,
            seed: self.seed,
        }
    }

    fn serve(
        &self,
        adaptive: Option<AdaptiveConfig>,
    ) -> Result<ServeResult, String> {
        let mut engine = SimEngine::new(
            self.slots,
            256,
            self.spec.clone(),
            SimCostModel::default(),
        );
        let mut prm = OraclePrm::new(0.1, self.seed ^ 7);
        let mut sched = Scheduler::new(
            self.sched_cfg(adaptive),
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(true);
        sched.serve(&self.trace).map_err(|e| e.to_string())
    }
}

// ---------------------------------------------------------------------------
// Policy-off byte-identity (tentpole acceptance).
// ---------------------------------------------------------------------------

#[test]
fn prop_adaptive_off_serve_is_byte_identical() {
    // `adaptive: None` must be today's serve, and the hooks themselves
    // must be decision-only: an armed-but-inert config (no rule can
    // fire) schedules byte-identically — same outcomes, same timeline,
    // same round count, audit on in both runs.
    check("adaptive_off_identity", 10, |rng| {
        let c = random_case(rng);
        let off = c.serve(None)?;
        let inert = c.serve(Some(inert_cfg()))?;
        prop_assert!(off.outcomes == inert.outcomes, "outcomes differ");
        prop_assert!(
            off.timeline.points == inert.timeline.points,
            "timeline differs"
        );
        prop_assert!(off.rounds == inert.rounds, "rounds differ");
        prop_assert!(
            off.adaptive.is_empty(),
            "policy-off serve recorded adaptive state"
        );
        prop_assert!(
            inert.adaptive.fast_path_requests == 0
                && inert.adaptive.spread_pruned_branches == 0
                && inert.adaptive.cap_tightened_requests == 0,
            "inert config took a scheduling decision"
        );
        Ok(())
    });
}

#[test]
fn prop_adaptive_off_cluster_r2_is_byte_identical() {
    // The same identity through the dispatch layer: a 2-replica cluster
    // serve with `adaptive: None` vs the inert config, audit on in every
    // replica — merged outcomes, assignments and per-replica timelines
    // must all agree, and the off run must report no adaptive state.
    check("adaptive_off_cluster_r2", 6, |rng| {
        let c = random_case(rng);
        let lb = LbPolicy::ALL[rng.below(LbPolicy::ALL.len())];
        let run = |adaptive: Option<AdaptiveConfig>| {
            let replicas = 2;
            let engines: Vec<Box<dyn Engine>> = (0..replicas)
                .map(|_| {
                    Box::new(SimEngine::new(
                        c.slots,
                        256,
                        c.spec.clone(),
                        SimCostModel::default(),
                    )) as Box<dyn Engine>
                })
                .collect();
            let prms: Vec<Box<dyn PrmScorer>> = (0..replicas)
                .map(|i| {
                    let seed =
                        c.seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
                    Box::new(OraclePrm::new(0.1, seed ^ 7))
                        as Box<dyn PrmScorer>
                })
                .collect();
            let ccfg = ClusterConfig {
                replicas,
                lb,
                sched: c.sched_cfg(adaptive),
                seed: c.seed,
                audit: true,
                gossip_rounds: 0,
                gossip_adapt: false,
                fault_plan: Default::default(),
                scale: None,
            };
            let (mut engines, mut prms) = (engines, prms);
            serve_cluster(&ccfg, &mut engines, &mut prms, &c.trace)
                .map_err(|e| format!("{lb:?}: {e}"))
        };
        let off = run(None)?;
        let inert = run(Some(inert_cfg()))?;
        prop_assert!(
            off.outcomes == inert.outcomes,
            "outcomes diverge under {lb:?}"
        );
        prop_assert!(
            off.assignments == inert.assignments,
            "assignments diverge under {lb:?}"
        );
        for (i, (a, b)) in off
            .replica_results
            .iter()
            .zip(&inert.replica_results)
            .enumerate()
        {
            prop_assert!(
                a.timeline.points == b.timeline.points,
                "replica {i} timeline diverges under {lb:?}"
            );
            prop_assert!(
                a.adaptive.is_empty(),
                "replica {i} recorded adaptive state with the layer off"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// NaN / unscored rewards fall back to the static policy (satellite).
// ---------------------------------------------------------------------------

/// A PRM that can only produce NaN — the pathological scorer the spread
/// and easy-classification rules must survive.
struct NanPrm;

impl PrmScorer for NanPrm {
    fn score(&mut self, seqs: &[&[Token]]) -> anyhow::Result<Vec<f32>> {
        Ok(vec![f32::NAN; seqs.len()])
    }

    fn describe(&self) -> String {
        "nan-prm".into()
    }
}

#[test]
fn nan_rewards_fall_back_to_static_policy() {
    // Aggressive adaptive thresholds, but every reward is NaN: the
    // spread rule must record a static fallback per request (never a
    // prune), the fast path must never classify a dataset easy (no
    // finite reward observations exist), and the serve must be
    // byte-identical to the same scripted serve with the layer off.
    let spec = TaskSpec::synth_gaokao();
    let trace = poisson_trace(&spec, 10, 2.0, 99);
    let run = |adaptive: Option<AdaptiveConfig>| -> ServeResult {
        let mut engine =
            SimEngine::new(8, 256, spec.clone(), SimCostModel::default());
        let mut prm = NanPrm;
        let cfg = SchedConfig {
            policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(16384, 16),
            adaptive,
            seed: 99,
        };
        let mut sched = Scheduler::new(
            cfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(true);
        sched.serve(&trace).expect("serve")
    };
    // Everything concentrates (tol 1.0 covers the whole reward range),
    // one sample arms the distribution rules, the fast-path reward bar
    // sits below any real reward — only the NaN guards stand between
    // this config and rewriting every request.
    let aggressive = AdaptiveConfig {
        spread_tol: 1.0,
        prune_keep: 1,
        tail_pct: 50.0,
        cap_slack: 1.0e9,
        min_samples: 1,
        fast_reward: -100.0,
        fast_len: 1.0e9,
    };
    let on = run(Some(aggressive));
    let off = run(None);
    assert_eq!(on.outcomes, off.outcomes, "NaN rewards changed scheduling");
    assert_eq!(
        on.timeline.points, off.timeline.points,
        "NaN rewards changed the timeline"
    );
    assert_eq!(on.rounds, off.rounds);
    assert_eq!(on.adaptive.fast_path_requests, 0, "easy off NaN rewards");
    assert_eq!(on.adaptive.spread_pruned_branches, 0, "pruned off NaN");
    assert_eq!(on.adaptive.cap_tightened_requests, 0);
    assert_eq!(
        on.adaptive.static_fallbacks,
        trace.len(),
        "every request must fall back exactly once"
    );
    assert!(on
        .adaptive
        .decisions
        .iter()
        .all(|d| d.kind == AdaptiveDecisionKind::StaticFallback));
    assert!(off.adaptive.is_empty());
}

// ---------------------------------------------------------------------------
// Fast-path capped-vote regression (satellite).
// ---------------------------------------------------------------------------

/// 8 warmup requests at t = 0 classify the dataset easy, then 8 late
/// arrivals route to the 1-branch fast path with a cap far below any
/// answer-bearing chain. Every one of them must still finalize exactly
/// once — through the exhaustion (capped-vote) path, never hanging on
/// the static quorum M = 2 its single branch can't reach.
fn fast_path_trace() -> (TaskSpec, Vec<Request>) {
    let spec = TaskSpec::synth_gaokao();
    let mut trace = batch_trace(&spec, 16, 7);
    for r in trace.iter_mut().skip(8) {
        r.arrival = 10_000.0; // long after every warmup finish
    }
    (spec, trace)
}

fn fast_path_cfg() -> AdaptiveConfig {
    AdaptiveConfig {
        spread_tol: -1.0, // spread rule inert: isolate the fast path
        prune_keep: 4,
        tail_pct: 100.0,
        cap_slack: 0.05, // fast-path cap ~ 5% of the mean chain: capped
        min_samples: 4,
        fast_reward: -1.0, // any scored dataset classifies easy
        fast_len: 1.0e9,
    }
}

fn run_fast_path(kv: KvConfig) -> (ServeResult, Vec<ServeEvent>) {
    let (spec, trace) = fast_path_trace();
    let mut engine =
        SimEngine::new(8, 256, spec, SimCostModel::default());
    let mut prm = OraclePrm::new(0.1, 7 ^ 7);
    let cfg = SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv,
        adaptive: Some(fast_path_cfg()),
        seed: 7,
    };
    let mut sched = Scheduler::new(
        cfg,
        &mut engine,
        &mut prm,
        ClockHandle::Sim(SimClock::new()),
    );
    sched.set_audit(true);
    let mut events = Vec::new();
    let res = sched
        .serve_with(&trace, &mut |ev| events.push(ev))
        .expect("serve");
    (res, events)
}

fn assert_fast_path_finalizes(res: &ServeResult, events: &[ServeEvent]) {
    assert_eq!(res.outcomes.len(), 16, "lost requests");
    assert_eq!(
        res.adaptive.fast_path_requests, 8,
        "every late arrival must route to the fast path"
    );
    let fast_ids: Vec<usize> = res
        .adaptive
        .decisions
        .iter()
        .filter_map(|d| match d.kind {
            AdaptiveDecisionKind::FastPath { .. } => Some(d.request),
            _ => None,
        })
        .collect();
    assert_eq!(fast_ids, (8..16).collect::<Vec<_>>());
    // Exactly one Finalized event per request — fast-path requests
    // included (the regression: a capped answerless 1-branch request
    // once waited forever on the unreachable static quorum).
    for r in 0..16usize {
        let finals = events
            .iter()
            .filter(|e| {
                matches!(e, ServeEvent::Finalized { request, .. }
                         if *request == r)
            })
            .count();
        assert_eq!(finals, 1, "request {r} finalized {finals} times");
    }
    let fast_outcomes: Vec<_> = res
        .outcomes
        .iter()
        .filter(|o| fast_ids.contains(&o.id))
        .collect();
    assert_eq!(fast_outcomes.len(), 8);
    for o in &fast_outcomes {
        assert_eq!(o.branches_started, 1, "fast path started extra branches");
        assert!(
            !o.response_lengths.is_empty(),
            "fast-path request finalized with nothing harvested"
        );
    }
    // The tiny cap truncates ahead of any answer for at least some of
    // them — the capped-vote path, not the quorum, finalized those.
    assert!(
        fast_outcomes.iter().any(|o| o.branches_completed == 0),
        "no fast-path request exercised the capped answerless path"
    );
}

#[test]
fn fast_path_capped_request_finalizes_via_capped_vote() {
    let (res, events) = run_fast_path(KvConfig::new(16384, 16));
    assert_fast_path_finalizes(&res, &events);
}

#[test]
fn fast_path_capped_request_finalizes_under_kv_preemption() {
    // Same regression with the memory-pressure path armed and a budget
    // tight enough (64 pages; the warmup batch wants far more) that
    // streamed admission and preemption are both in play.
    let kv = KvConfig::new(16 * 64, 16)
        .with_stream_admission(true)
        .with_preemption(true);
    let (res, events) = run_fast_path(kv);
    assert_fast_path_finalizes(&res, &events);
}

// ---------------------------------------------------------------------------
// Mixed easy/hard workload determinism (satellite).
// ---------------------------------------------------------------------------

#[test]
fn mixed_workload_adaptive_serve_is_deterministic() {
    // Same seed ⇒ identical trace ⇒ identical outcomes AND identical
    // adaptive decision log, twice over. The decision log is the
    // sensitive part: it would diverge on any hidden iteration-order or
    // RNG dependence in the policy layer.
    let easy = TaskSpec::synth_gaokao();
    let hard = TaskSpec::synth_gpqa();
    let cfg = AdaptiveConfig {
        spread_tol: 2.0, // whole reward range: the spread rule fires often
        prune_keep: 2,
        tail_pct: 90.0,
        cap_slack: 1.25,
        min_samples: 4,
        fast_reward: 0.0,
        fast_len: 256.0,
    };
    let run = || -> ServeResult {
        let trace = mixed_trace(&easy, &hard, 48, 2.0, 1234, 0.5);
        let mut engine =
            SimEngine::new(8, 256, easy.clone(), SimCostModel::default());
        let mut prm = OraclePrm::new(0.1, 1234 ^ 7);
        let scfg = SchedConfig {
            policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(32768, 16),
            adaptive: Some(cfg),
            seed: 1234,
        };
        let mut sched = Scheduler::new(
            scfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(true);
        sched.serve(&trace).expect("serve")
    };
    let a = run();
    let b = run();
    assert_eq!(a.outcomes, b.outcomes, "outcomes diverged across reruns");
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(
        a.adaptive.decisions, b.adaptive.decisions,
        "adaptive decisions diverged across reruns"
    );
    assert_eq!(a.adaptive.fast_path_requests, b.adaptive.fast_path_requests);
    assert_eq!(
        a.adaptive.spread_pruned_branches,
        b.adaptive.spread_pruned_branches
    );
    assert!(
        !a.adaptive.decisions.is_empty(),
        "the adaptive layer never acted on the mixed workload"
    );
    assert_eq!(a.outcomes.len(), 48, "lost requests");
}
