//! ISSUE 6: fault-tolerant elastic cluster — failure injection,
//! in-flight re-dispatch, gossip retraction, and the scale controller.
//!
//! The contract under test: the fault layer is *additive*. An armed but
//! inert layer (empty plan, no-op scale controller) must leave a serve
//! byte-identical to a plan-less one; a scripted failure must cost
//! re-dispatch latency, never correctness — every request still gets
//! exactly one outcome, routing never selects a down replica, and a
//! restarted replica re-warms through the ordinary gossip path.

use sart::cluster::{
    serve_cluster, ClusterConfig, FaultPlan, LbPolicy, ScaleConfig,
    REPLICA_SEED_STRIDE,
};
use sart::coordinator::{KvConfig, Policy, SchedConfig};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::prm::{OraclePrm, PrmScorer};
use sart::prop_assert;
use sart::testkit::check;
use sart::util::rng::Rng;
use sart::workload::{
    batch_trace, poisson_trace, templated_trace, Request, TaskSpec,
};

fn sched_cfg(seed: u64, kv_tokens: usize, cache_pages: usize) -> SchedConfig {
    SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(kv_tokens, 16)
            .with_prefix_cache(cache_pages),
        adaptive: None,
        seed,
    }
}

fn stacks(
    n: usize,
    seed: u64,
    cost: SimCostModel,
) -> (Vec<Box<dyn Engine>>, Vec<Box<dyn PrmScorer>>) {
    let spec = TaskSpec::synth_gaokao();
    let engines: Vec<Box<dyn Engine>> = (0..n)
        .map(|_| {
            let mut e = SimEngine::new(8, 512, spec.clone(), cost);
            e.set_prompt_bucket(256);
            Box::new(e) as Box<dyn Engine>
        })
        .collect();
    let prms: Vec<Box<dyn PrmScorer>> = (0..n)
        .map(|i| {
            let s = seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
            Box::new(OraclePrm::new(0.1, s ^ 7)) as Box<dyn PrmScorer>
        })
        .collect();
    (engines, prms)
}

fn base_cfg(replicas: usize, lb: LbPolicy, seed: u64) -> ClusterConfig {
    ClusterConfig {
        replicas,
        lb,
        sched: sched_cfg(seed, 16 * 512, 0),
        seed,
        audit: true,
        gossip_rounds: 0,
        gossip_adapt: false,
        fault_plan: FaultPlan::default(),
        scale: None,
    }
}

#[test]
fn prop_armed_but_inert_fault_layer_is_byte_identical() {
    // ISSUE 6 acceptance: the zero-fault path through the fault-aware
    // dispatcher must be byte-identical to a plan-less serve — same
    // assignments, outcomes, timelines and round counts, audit on. The
    // armed twin carries an empty fault plan *and* a scale controller
    // whose thresholds are unreachable (all replicas live, up-threshold
    // astronomically high, scale-down disabled), so every line of the
    // event pump runs and must take no action.
    check("inert_fault_layer_identity", 8, |rng| {
        let seed = rng.next_u64();
        let replicas = 2 + rng.below(3); // 2..=4
        let lbs = [
            LbPolicy::RoundRobin,
            LbPolicy::JoinShortestQueue,
            LbPolicy::PowerOfTwoChoices,
            LbPolicy::PrefixAffinity,
        ];
        let lb = lbs[rng.below(lbs.len())];
        let spec = TaskSpec::synth_gaokao();
        let trace = poisson_trace(
            &spec,
            6 + rng.below(10),
            0.5 + 3.0 * rng.f64(),
            seed,
        );
        let serve = |cfg: &ClusterConfig| {
            let (mut engines, mut prms) =
                stacks(replicas, seed, SimCostModel::default());
            serve_cluster(cfg, &mut engines, &mut prms, &trace)
                .map_err(|e| e.to_string())
        };
        let plain = serve(&base_cfg(replicas, lb, seed))?;
        let mut armed_cfg = base_cfg(replicas, lb, seed);
        armed_cfg.scale = Some(ScaleConfig {
            min_live: replicas,
            scale_up_queue: 1_000_000,
            scale_up_prefill_tokens: 0,
            scale_up_pressure: 0.0,
            scale_down_queue: 0,
            cooldown_arrivals: 0,
        });
        let armed = serve(&armed_cfg)?;
        prop_assert!(
            plain.assignments == armed.assignments,
            "routing diverged under the inert fault layer"
        );
        prop_assert!(plain.outcomes == armed.outcomes, "outcomes diverged");
        for (i, (p, a)) in plain
            .replica_results
            .iter()
            .zip(&armed.replica_results)
            .enumerate()
        {
            prop_assert!(
                p.timeline.points == a.timeline.points,
                "replica {i} timeline diverged"
            );
            prop_assert!(p.rounds == a.rounds, "replica {i} rounds diverged");
        }
        prop_assert!(
            armed.fault == Default::default(),
            "inert layer reported actions: {:?}",
            armed.fault
        );
        prop_assert!(
            armed.outcomes.iter().all(|o| o.redispatches == 0),
            "inert layer re-dispatched a request"
        );
        Ok(())
    });
}

#[test]
fn failure_with_in_flight_work_redispatches_and_loses_nothing() {
    // A batch of 12 requests lands on 4 replicas round-robin; replica 1
    // dies 10 ms in — far less than any request takes — so all three of
    // its requests are mid-flight and must be re-dispatched. Every trace
    // position still gets exactly one outcome, none of them served by
    // the dead replica, and the detour is visible as latency.
    let seed = 42;
    let replicas = 4;
    let spec = TaskSpec::synth_gaokao();
    let trace = batch_trace(&spec, 12, seed);
    let mut cfg = base_cfg(replicas, LbPolicy::RoundRobin, seed);
    cfg.fault_plan = FaultPlan::parse("fail@0.01:1").unwrap();
    let (mut engines, mut prms) =
        stacks(replicas, seed, SimCostModel::default());
    let res = serve_cluster(&cfg, &mut engines, &mut prms, &trace)
        .expect("faulted serve must complete");

    assert_eq!(res.outcomes.len(), trace.len(), "requests lost");
    for (o, r) in res.outcomes.iter().zip(&trace) {
        assert_eq!(o.id, r.id, "outcome order broken");
        assert_eq!(o.arrival, r.arrival, "original arrival not restored");
        assert!(o.finished_at >= o.arrival, "time travel");
    }
    assert_eq!(res.fault.failures, 1);
    assert_eq!(res.fault.restarts, 0);
    // Round-robin put trace positions 1, 5, 9 on replica 1; none were
    // finishable in 10 ms, so all three detoured exactly once.
    assert_eq!(res.fault.redispatches, 3);
    assert_eq!(res.fault.requests_redispatched, 3);
    let total: usize = res.outcomes.iter().map(|o| o.redispatches).sum();
    assert_eq!(total, res.fault.redispatches, "per-outcome counts drifted");
    for (pos, o) in res.outcomes.iter().enumerate() {
        if o.redispatches > 0 {
            assert_ne!(
                res.assignments[pos], 1,
                "request {pos} still served by the dead replica"
            );
        }
    }
    // The dead replica's timeline closes with an explicit zero-occupancy
    // sample at the failure instant.
    let last = res.replica_results[1].timeline.points.last().unwrap();
    assert_eq!(last.running_branches, 0);
    assert_eq!(last.kv_pages_used, 0);
}

#[test]
fn routing_never_selects_a_down_replica() {
    // Replica 1 is down for t ∈ [3, 8). Every request arriving in that
    // window must route elsewhere, and requests re-dispatched at the
    // failure must land on survivors.
    let seed = 7;
    let replicas = 4;
    let spec = TaskSpec::synth_gaokao();
    let trace = poisson_trace(&spec, 24, 2.0, seed);
    let mut cfg = base_cfg(replicas, LbPolicy::RoundRobin, seed);
    cfg.fault_plan = FaultPlan::parse("fail@3.0:1,restart@8.0:1").unwrap();
    let (mut engines, mut prms) =
        stacks(replicas, seed, SimCostModel::default());
    let res = serve_cluster(&cfg, &mut engines, &mut prms, &trace)
        .expect("fail+restart serve must complete");

    assert_eq!(res.outcomes.len(), trace.len());
    assert_eq!(res.fault.failures, 1);
    assert_eq!(res.fault.restarts, 1);
    for (pos, r) in trace.iter().enumerate() {
        let downtime = (3.0..8.0).contains(&r.arrival);
        if downtime || res.outcomes[pos].redispatches > 0 {
            assert_ne!(
                res.assignments[pos], 1,
                "request {pos} (arrival {:.2}) routed to the down replica",
                r.arrival
            );
        }
    }
}

#[test]
fn restarted_replica_rewarms_through_gossip() {
    // Prefix-affinity + gossip, period 1: replica 1 advertises, dies
    // (its table row is retracted), restarts cold, and must re-advertise
    // a fresh Full snapshot once it earns work again — its digest row
    // grows back from zero through the ordinary gossip path.
    let seed = 11;
    let replicas = 3;
    let spec = TaskSpec::synth_gaokao();
    // Mixed workload: shared headers give the table something to
    // advertise, the cold remainder keeps p2c fallback routes flowing to
    // the rejoined (empty-cache) replica.
    let trace = templated_trace(&spec, 48, 3.0, seed, 0.6, 2, 3);
    let t_mid = trace[trace.len() / 3].arrival;
    let t_back = trace[trace.len() / 2].arrival;
    assert!(t_back > t_mid, "trace too short to straddle the outage");
    let mut cfg = base_cfg(replicas, LbPolicy::PrefixAffinity, seed);
    cfg.sched = sched_cfg(seed, 16 * 512, 32);
    cfg.gossip_rounds = 1;
    cfg.fault_plan =
        FaultPlan::parse(&format!("fail@{t_mid}:1,restart@{t_back}:1"))
            .unwrap();
    let (mut engines, mut prms) =
        stacks(replicas, seed, SimCostModel::default());
    let res = serve_cluster(&cfg, &mut engines, &mut prms, &trace)
        .expect("rewarm serve must complete");

    assert_eq!(res.outcomes.len(), trace.len());
    assert_eq!(res.fault.failures, 1);
    assert_eq!(res.fault.restarts, 1);
    assert_eq!(res.gossip.probe_calls, 0, "gossip serve must not probe");
    assert!(
        res.digest_rows[1] > 0,
        "restarted replica never re-advertised (rows: {:?})",
        res.digest_rows
    );
    // Every replica's first push is a Full snapshot, and the rejoined
    // replica's cold cache forces one more.
    assert!(
        res.gossip.full_advertisements >= replicas + 1,
        "expected a post-restart full snapshot: {} full advertisements",
        res.gossip.full_advertisements
    );
    assert!(
        res.gossip.delta_advertisements > 0,
        "steady-state advertisements should be deltas"
    );
}

#[test]
fn failure_during_chunked_prefill_releases_pledges() {
    // Chunked prefill holds pledged pages for mid-stream admissions; a
    // failure in that window must release them cleanly (fail_and_drain
    // verifies kv invariants and zero residual pages internally, turning
    // a leak into a serve error). Long cold headers + a 24-token chunk +
    // per-token prefill cost keep replica 1 mid-stream at t = 0.01.
    let seed = 5;
    let replicas = 2;
    let spec = TaskSpec::synth_gaokao();
    let trace = templated_trace(&spec, 10, 0.0, seed, 1.0, 4, 4);
    let mut cfg = base_cfg(replicas, LbPolicy::JoinShortestQueue, seed);
    cfg.sched = sched_cfg(seed, 16 * 2048, 32);
    cfg.sched.kv = cfg.sched.kv.clone().with_chunked_prefill(24, 48);
    cfg.fault_plan = FaultPlan::parse("fail@0.01:1").unwrap();
    let cost = SimCostModel {
        prefill_per_token: 0.2e-3,
        ..SimCostModel::default()
    };
    let (mut engines, mut prms) = stacks(replicas, seed, cost);
    let res = serve_cluster(&cfg, &mut engines, &mut prms, &trace)
        .expect("mid-prefill failure must drain cleanly");

    assert_eq!(res.outcomes.len(), trace.len(), "requests lost");
    assert_eq!(res.fault.failures, 1);
    assert!(
        res.fault.redispatches >= 1,
        "replica 1 had mid-stream work to re-dispatch"
    );
    let last = res.replica_results[1].timeline.points.last().unwrap();
    assert_eq!(last.kv_pages_used, 0, "failed replica leaked pages");
    assert_eq!(last.queued_prefill_tokens, 0, "prefill backlog survived");
}

#[test]
fn scale_controller_respects_hysteresis_and_floor() {
    // Start 1-of-4 live under a burst, then let the queue drain: the
    // controller must scale up under pressure, scale down in the calm
    // tail, and never drain below the floor. A second burst re-activates
    // a drained (warm) replica.
    let seed = 13;
    let replicas = 4;
    let spec = TaskSpec::synth_gaokao();
    let mut trace = batch_trace(&spec, 10, seed);
    // Calm tail: a few spaced-out stragglers long after the burst.
    let tail = poisson_trace(&spec, 6, 0.2, seed ^ 1);
    for (i, mut r) in tail.into_iter().enumerate() {
        r.id = trace.len();
        r.arrival += 20.0 + 5.0 * i as f64;
        trace.push(r);
    }
    let mut cfg = base_cfg(replicas, LbPolicy::JoinShortestQueue, seed);
    cfg.scale = Some(ScaleConfig {
        min_live: 1,
        scale_up_queue: 2,
        scale_up_prefill_tokens: 0,
        scale_up_pressure: 0.0,
        scale_down_queue: 1,
        cooldown_arrivals: 1,
    });
    let (mut engines, mut prms) =
        stacks(replicas, seed, SimCostModel::default());
    let res = serve_cluster(&cfg, &mut engines, &mut prms, &trace)
        .expect("scaled serve must complete");

    assert_eq!(res.outcomes.len(), trace.len(), "requests lost");
    assert!(res.fault.scale_ups >= 1, "burst never scaled up");
    assert!(res.fault.scale_downs >= 1, "calm tail never scaled down");
    assert_eq!(res.fault.failures, 0);
    assert_eq!(res.fault.redispatches, 0, "scaling must not re-dispatch");
    // Standby replicas that were never activated served nothing.
    for (pos, &rep) in res.assignments.iter().enumerate() {
        assert!(rep < replicas, "request {pos} unassigned");
    }
}

#[test]
fn fault_plan_validation_errors_are_caught() {
    let seed = 3;
    let spec = TaskSpec::synth_gaokao();
    let trace = batch_trace(&spec, 4, seed);
    let serve = |cfg: &ClusterConfig| {
        let (mut engines, mut prms) =
            stacks(cfg.replicas, seed, SimCostModel::default());
        serve_cluster(cfg, &mut engines, &mut prms, &trace)
    };
    // Plan names a replica outside the cluster.
    let mut cfg = base_cfg(2, LbPolicy::RoundRobin, seed);
    cfg.fault_plan = FaultPlan::parse("fail@1.0:5").unwrap();
    assert!(serve(&cfg).is_err());
    // Restarting a replica that never failed.
    let mut cfg = base_cfg(2, LbPolicy::RoundRobin, seed);
    cfg.fault_plan = FaultPlan::parse("restart@1.0:1").unwrap();
    assert!(serve(&cfg).is_err());
    // Failing the same replica twice without a restart in between.
    let mut cfg = base_cfg(2, LbPolicy::RoundRobin, seed);
    cfg.fault_plan = FaultPlan::parse("fail@0.5:1,fail@1.0:1").unwrap();
    assert!(serve(&cfg).is_err());
    // Failing every replica while requests are in flight strands them —
    // the serve must error, not lose requests silently. (10 ms in, no
    // request has finished yet.)
    let mut cfg = base_cfg(2, LbPolicy::RoundRobin, seed);
    cfg.fault_plan = FaultPlan::parse("fail@0.01:0,fail@0.01:1").unwrap();
    assert!(serve(&cfg).is_err());
    // Scale floor above the replica count.
    let mut cfg = base_cfg(2, LbPolicy::RoundRobin, seed);
    cfg.scale = Some(ScaleConfig {
        min_live: 3,
        scale_up_queue: 4,
        scale_up_prefill_tokens: 0,
        scale_up_pressure: 0.0,
        scale_down_queue: 0,
        cooldown_arrivals: 1,
    });
    assert!(serve(&cfg).is_err());
}

/// Deterministic harness sanity: the same faulted serve twice must agree
/// bit-for-bit (virtual-time fault injection has no hidden entropy).
#[test]
fn faulted_serve_is_deterministic() {
    let seed = 23;
    let replicas = 3;
    let spec = TaskSpec::synth_gaokao();
    let trace = poisson_trace(&spec, 16, 2.0, seed);
    let mut cfg = base_cfg(replicas, LbPolicy::PowerOfTwoChoices, seed);
    cfg.fault_plan = FaultPlan::parse("fail@2.0:2,restart@5.0:2").unwrap();
    let run = || {
        let (mut engines, mut prms) =
            stacks(replicas, seed, SimCostModel::default());
        serve_cluster(&cfg, &mut engines, &mut prms, &trace)
            .expect("deterministic faulted serve")
    };
    let a = run();
    let b = run();
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.outcomes, b.outcomes);
    assert_eq!(a.fault, b.fault);
    for (x, y) in a.replica_results.iter().zip(&b.replica_results) {
        assert_eq!(x.timeline.points, y.timeline.points);
    }
}
