//! ISSUE 5: cross-replica prefix-digest gossip routing, property-tested
//! against the ground-truth probe policy.
//!
//! The contract under test: `--gossip-rounds` only changes *how*
//! `PrefixAffinity` learns where prefixes live (advertised digest tables
//! vs per-replica tree probes), never what a serve computes. Fresh
//! advertisements (period 1) must route byte-identically to probes;
//! probe mode (period 0) is the unchanged pre-gossip path; a one-replica
//! cluster with gossip on must still reduce exactly to
//! `Scheduler::serve`; and a stale table entry — the digest of a prefix
//! the replica has since evicted — must only cost a re-prefill (counted
//! as a `stale_hit`), never correctness.

use sart::cluster::{
    serve_cluster, ClusterConfig, DigestTable, LbPolicy, REPLICA_SEED_STRIDE,
};
use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::kvcache::{prompt_page_digests, AdmissionRequest, KvCacheManager};
use sart::prm::{OraclePrm, PrmScorer};
use sart::prop_assert;
use sart::testkit::check;
use sart::tokenizer::Token;
use sart::util::clock::SimClock;
use sart::util::rng::Rng;
use sart::workload::{
    few_shot_header, templated_trace, Question, Request, TaskSpec,
};

/// One gossip test configuration over a templated (prefix-heavy) trace.
struct GossipCase {
    policy: Policy,
    slots: usize,
    t_round: usize,
    kv_tokens: usize,
    prefix_cache_pages: usize,
    seed: u64,
    spec: TaskSpec,
    trace: Vec<Request>,
}

impl GossipCase {
    fn random(rng: &mut Rng) -> GossipCase {
        let n = 1 << rng.below(3); // 1, 2, 4
        let policy = Policy::Sart {
            n,
            m: (n / 2).max(1),
            alpha: (0.3 + 0.4 * rng.f64()) as f32,
            beta: (n / 2).max(1),
        };
        // Headered prompts reach ~11 pages; always keep one full request
        // admissible so a serve cannot stall.
        let min_pages = 11 + policy.n_branches() * 14 + 4;
        let seed = rng.next_u64();
        let spec = TaskSpec::synth_gaokao();
        let n_req = 6 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let share = 0.5 + 0.45 * rng.f64();
        let trace = templated_trace(
            &spec,
            n_req,
            rate,
            seed,
            share,
            1 + rng.below(3),
            2 + rng.below(2),
        );
        GossipCase {
            policy,
            slots: 2 + rng.below(14),
            t_round: 8 + rng.below(24),
            kv_tokens: 16 * (min_pages + rng.below(512)),
            // Occasionally run cache-off (both modes degenerate to p2c
            // and must still agree); otherwise small budgets keep LRU
            // eviction in play mid-serve.
            prefix_cache_pages: if rng.chance(0.15) {
                0
            } else {
                8 + rng.below(64)
            },
            seed,
            spec,
            trace,
        }
    }

    fn sched_cfg(&self) -> SchedConfig {
        SchedConfig {
            policy: self.policy,
            t_round: self.t_round,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(self.kv_tokens, 16)
                .with_prefix_cache(self.prefix_cache_pages),
            adaptive: None,
            seed: self.seed,
        }
    }

    fn stacks(
        &self,
        n: usize,
    ) -> (Vec<Box<dyn Engine>>, Vec<Box<dyn PrmScorer>>) {
        let engines: Vec<Box<dyn Engine>> = (0..n)
            .map(|_| {
                let mut e = SimEngine::new(
                    self.slots,
                    512,
                    self.spec.clone(),
                    SimCostModel::default(),
                );
                e.set_prompt_bucket(256);
                Box::new(e) as Box<dyn Engine>
            })
            .collect();
        let prms: Vec<Box<dyn PrmScorer>> = (0..n)
            .map(|i| {
                let seed =
                    self.seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
                Box::new(OraclePrm::new(0.1, seed ^ 7)) as Box<dyn PrmScorer>
            })
            .collect();
        (engines, prms)
    }

    fn serve(
        &self,
        replicas: usize,
        gossip_rounds: usize,
    ) -> Result<sart::cluster::ClusterResult, String> {
        let (mut engines, mut prms) = self.stacks(replicas);
        let ccfg = ClusterConfig {
            replicas,
            lb: LbPolicy::PrefixAffinity,
            sched: self.sched_cfg(),
            seed: self.seed,
            audit: true,
            gossip_rounds,
            gossip_adapt: false,
            fault_plan: Default::default(),
            scale: None,
        };
        serve_cluster(&ccfg, &mut engines, &mut prms, &self.trace)
            .map_err(|e| format!("gossip={gossip_rounds}: {e}"))
    }
}

#[test]
fn prop_gossip_fresh_matches_probe_routing_exactly() {
    // ISSUE 5 acceptance: with fresh-every-round advertisements (period
    // 1 — a replica's tree only changes inside its own steps, so the
    // table equals the live trees at every decision), gossip routing
    // must pick byte-identical replicas to the probe-based policy on
    // templated traces across seeds: same assignments, same outcomes,
    // same per-replica timelines, audit on. The probe run pays R tree
    // probes per arrival; the gossip run must pay none.
    check("gossip_fresh_identity", 8, |rng| {
        let case = GossipCase::random(rng);
        let replicas = 2 + rng.below(3); // 2..=4
        let probe = case.serve(replicas, 0)?;
        let fresh = case.serve(replicas, 1)?;
        prop_assert!(
            probe.assignments == fresh.assignments,
            "routing diverged: probe {:?} vs gossip {:?}",
            probe.assignments,
            fresh.assignments
        );
        prop_assert!(probe.outcomes == fresh.outcomes, "outcomes diverged");
        for (i, (p, g)) in probe
            .replica_results
            .iter()
            .zip(&fresh.replica_results)
            .enumerate()
        {
            prop_assert!(
                p.timeline.points == g.timeline.points,
                "replica {i} timeline diverged"
            );
            prop_assert!(
                p.rounds == g.rounds,
                "replica {i} round count diverged"
            );
        }
        prop_assert!(
            probe.gossip.probe_calls == replicas * case.trace.len(),
            "probe mode must scan every replica per arrival: {} != {}",
            probe.gossip.probe_calls,
            replicas * case.trace.len()
        );
        prop_assert!(
            probe.gossip.advertisements == 0
                && probe.gossip.digest_table_digests == 0,
            "probe mode must not touch the digest table"
        );
        prop_assert!(
            fresh.gossip.probe_calls == 0,
            "gossip routing made {} tree probes",
            fresh.gossip.probe_calls
        );
        Ok(())
    });
}

#[test]
fn prop_gossip_r1_cluster_matches_single_serve() {
    // With one replica, placement is forced, so gossip must cost nothing:
    // the cluster serve stays byte-identical to `Scheduler::serve` on the
    // same trace with gossip on (any period), audit on.
    check("gossip_r1_identity", 8, |rng| {
        let case = GossipCase::random(rng);
        let gossip_rounds = 1 + rng.below(8);
        let mut engine = SimEngine::new(
            case.slots,
            512,
            case.spec.clone(),
            SimCostModel::default(),
        );
        engine.set_prompt_bucket(256);
        let mut prm = OraclePrm::new(0.1, case.seed ^ 7);
        let mut sched = Scheduler::new(
            case.sched_cfg(),
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        let single = sched.serve(&case.trace).map_err(|e| e.to_string())?;
        let res = case.serve(1, gossip_rounds)?;
        prop_assert!(
            res.outcomes == single.outcomes,
            "R=1 outcomes diverge with gossip on"
        );
        prop_assert!(
            res.replica_results[0].timeline.points == single.timeline.points,
            "R=1 timeline diverges with gossip on"
        );
        prop_assert!(
            res.replica_results[0].rounds == single.rounds,
            "R=1 round count diverges with gossip on"
        );
        prop_assert!(
            res.gossip.probe_calls == 0,
            "R=1 routing must not probe"
        );
        Ok(())
    });
}

/// A page-aligned synthetic prompt (kv-level staleness tests).
fn tokens(base: i32, len: usize) -> Vec<Token> {
    (base..base + len as i32).collect()
}

#[test]
fn stale_table_entry_survives_eviction_until_readvertised() {
    // Satellite regression, kv level: (a) after the replica evicts a
    // prefix, the digest table still names it — routing on it is merely
    // stale; (c) the next advertisement retracts it.
    let mut kv = KvCacheManager::with_prefix_cache(16 * 256, 16, 4);
    let a = tokens(0, 64); // 4 pages — fills the retention budget
    let adm = kv
        .admit(&AdmissionRequest::monolithic(&a, 16, 1))
        .unwrap()
        .into_admission()
        .unwrap();
    for b in adm.branches {
        kv.release_branch(b).unwrap();
    }
    assert_eq!(kv.cached_prefix_tokens(&a), 64);

    let mut table = DigestTable::new(1, 16);
    table.advertise(0, kv.advertised_digests());
    assert_eq!(table.lookup(&a), (64, vec![0]));

    // Churn the pool: a different 4-page prefix evicts every page of `a`.
    let b = tokens(5000, 64);
    let adm = kv
        .admit(&AdmissionRequest::monolithic(&b, 16, 1))
        .unwrap()
        .into_admission()
        .unwrap();
    for br in adm.branches {
        kv.release_branch(br).unwrap();
    }
    assert_eq!(kv.cached_prefix_tokens(&a), 0, "a must be fully evicted");
    kv.check_invariants().unwrap();

    // (a) The table has not heard: it still names the evicted prefix.
    assert_eq!(
        table.lookup(&a),
        (64, vec![0]),
        "pre-advertisement table must still name the evicted prefix"
    );
    for d in prompt_page_digests(&a, 16) {
        assert!(table.contains(0, d));
        assert!(!kv.has_digest(d));
    }

    // (c) The next advertisement retracts it (and names the newcomer).
    table.advertise(0, kv.advertised_digests());
    assert_eq!(table.lookup(&a), (0, Vec::new()));
    assert_eq!(table.lookup(&b), (64, vec![0]));
}

#[test]
fn stale_gossip_hit_reprefills_and_counts() {
    // Satellite regression, serve level: force an eviction between
    // advertisements and pin that the routed replica simply re-prefills
    // — every request completes, and the dispatcher's `stale_hits`
    // counter records the broken promise. The scenario:
    //
    //   phase 1: template-A requests, spaced out, so both replicas
    //     intern A's header and advertise it (gossip period 25 steps);
    //   phase 2: a burst of template-B requests at one instant — the
    //     table freezes (advertisement periods are measured in replica
    //     steps, and no steps happen between same-instant arrivals);
    //   final: one more template-A request 10 ms later. It routes on the
    //     frozen table entry, queues behind the B's (the kv budget fits
    //     one request at a time), and by the time it admits, the B
    //     serves have evicted A's pages from the retention pool.
    let spec = TaskSpec::synth_gaokao();
    let header_a = few_shot_header(&spec, 1, 3);
    let header_b = few_shot_header(&spec, 2, 3);
    assert_ne!(header_a, header_b);
    let mut qrng = Rng::new(97);
    let mut trace: Vec<Request> = Vec::new();
    let mut push = |trace: &mut Vec<Request>, header: &[Token], t: f64| {
        let id = trace.len();
        trace.push(Request {
            id,
            question: Question::sample(&spec, &mut qrng),
            arrival: t,
            dataset: spec.name.clone(),
            header: header.to_vec(),
        });
    };
    for i in 0..10 {
        push(&mut trace, &header_a, 1.5 * i as f64);
    }
    let t_burst = 1.5 * 9.0 + 10.0;
    for _ in 0..8 {
        push(&mut trace, &header_b, t_burst);
    }
    push(&mut trace, &header_a, t_burst + 0.01);

    // Budgets: the kv capacity fits exactly one request (n=4 branches ×
    // 14 pages + the ~11-page headered prompt), so per-replica serving
    // is serial and the final A request admits only after every queued B
    // released; the retention budget holds one template's full pages
    // plus one, so the B releases evict A's retained pages first.
    let worst_prompt_pages = {
        let a = (header_a.len() + 27).div_ceil(16);
        let b = (header_b.len() + 27).div_ceil(16);
        a.max(b)
    };
    let request_pages = worst_prompt_pages + 4 * 14;
    let full_a_pages = (header_a.len() + 27) / 16;
    let sched = SchedConfig {
        policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
        t_round: 16,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(16 * (request_pages + 6), 16)
            .with_prefix_cache(full_a_pages + 1),
        adaptive: None,
        seed: 42,
    };
    let replicas = 2;
    let mut engines: Vec<Box<dyn Engine>> = (0..replicas)
        .map(|_| {
            let mut e = SimEngine::new(
                8,
                512,
                spec.clone(),
                SimCostModel::default(),
            );
            e.set_prompt_bucket(256);
            Box::new(e) as Box<dyn Engine>
        })
        .collect();
    let mut prms: Vec<Box<dyn PrmScorer>> = (0..replicas)
        .map(|i| {
            let seed = 42u64 ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
            Box::new(OraclePrm::new(0.1, seed ^ 7)) as Box<dyn PrmScorer>
        })
        .collect();
    let ccfg = ClusterConfig {
        replicas,
        lb: LbPolicy::PrefixAffinity,
        sched,
        seed: 42,
        audit: true,
        gossip_rounds: 25,
        gossip_adapt: false,
        fault_plan: Default::default(),
        scale: None,
    };
    let res = serve_cluster(&ccfg, &mut engines, &mut prms, &trace)
        .expect("stale-hit serve must still complete every request");

    assert_eq!(res.outcomes.len(), trace.len(), "lost requests");
    for (o, r) in res.outcomes.iter().zip(&trace) {
        assert_eq!(o.id, r.id, "merge order broken");
        assert!(o.finished_at >= o.arrival, "time travel");
    }
    assert_eq!(res.gossip.probe_calls, 0, "gossip serve must not probe");
    assert!(
        res.gossip.advertisements > 0,
        "phase 1 must have produced advertisements"
    );
    assert!(
        res.gossip.stale_hits >= 1,
        "the final template-A request must land on a stale table entry \
         (advertisements: {}, table digests: {})",
        res.gossip.advertisements,
        res.gossip.digest_table_digests
    );
}
