//! Memory-pressure serving: stream-aware admission and reward-driven
//! preemption (ISSUE 9).
//!
//! The contract:
//!
//! * With the pressure knobs **off** nothing changes — and with the
//!   knobs **on** under a budget generous enough that no admission is
//!   ever deferred, the serve is *byte-identical* to knobs-off: same
//!   outcomes, same timeline, same round count, audit on. Streamed
//!   admission only changes *pledge* accounting (the timeline samples
//!   used pages, which accrue chunk by chunk either way) and priority
//!   bookkeeping is invisible until a deferral consults it. Checked on
//!   the single-engine path and at R = 2 cluster scale.
//! * Under a genuinely tight budget, preemption swaps out the
//!   lowest-reward running branches of an admitted request to let a
//!   blocked one in: the victim request records `preemptions > 0`, the
//!   blocked request admits strictly earlier than with preemption off,
//!   and every preempted branch still finishes (recompute-on-resume) —
//!   zero lost requests, audit on.
//! * Audit mode rebuilds the manager's grown-pledge and priority
//!   structures from scratch every round (`check_invariants`), so a
//!   tight-budget streamed + preempting serve with audit on pins the
//!   incremental bookkeeping; the kv-level test below drives the same
//!   rebuild through a hand-rolled stream.

use sart::cluster::{serve_cluster, ClusterConfig, LbPolicy};
use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::kvcache::{AdmissionOutcome, AdmissionRequest, KvCacheManager};
use sart::prm::{OraclePrm, PrmScorer};
use sart::prop_assert;
use sart::testkit::check;
use sart::util::clock::SimClock;
use sart::util::rng::Rng;
use sart::workload::{batch_trace, templated_trace, Request, TaskSpec};

fn random_policy(rng: &mut Rng) -> Policy {
    let n = 1 << rng.below(4); // 1,2,4,8
    match rng.below(4) {
        0 => Policy::Vanilla,
        1 => Policy::SelfConsistency { n },
        2 => Policy::SartNoPrune { n, m: (n / 2).max(1) },
        _ => Policy::Sart {
            n,
            m: (n / 2).max(1),
            alpha: (0.3 + 0.4 * rng.f64()) as f32,
            beta: (n / 2).max(1),
        },
    }
}

/// One serve configuration; the pressure knobs vary per run.
struct Case {
    policy: Policy,
    slots: usize,
    t_round: usize,
    kv_tokens: usize,
    prefix_cache_pages: usize,
    chunk: usize,
    budget: usize,
    seed: u64,
    spec: TaskSpec,
}

impl Case {
    /// `generous = true` sizes the kv budget so every request of the
    /// trace could be resident at once (no admission ever defers);
    /// `false` leaves barely one full request admissible, the
    /// always-makes-progress floor.
    fn random(rng: &mut Rng, n_req: usize, generous: bool) -> Case {
        let policy = random_policy(rng);
        // Headered prompts reach ~11 pages; a branch reservation is
        // pages_for(224) = 14 pages.
        let min_pages = 11 + policy.n_branches() * 14 + 4;
        let kv_pages = if generous {
            n_req * min_pages + rng.below(256)
        } else {
            min_pages + rng.below(24)
        };
        let chunk = 8 + rng.below(48);
        Case {
            policy,
            slots: 2 + rng.below(14),
            t_round: 8 + rng.below(24),
            kv_tokens: 16 * kv_pages,
            prefix_cache_pages: if rng.chance(0.5) {
                0
            } else {
                4 + rng.below(64)
            },
            chunk,
            budget: chunk * (1 + rng.below(4)),
            seed: rng.next_u64(),
            spec: TaskSpec::synth_gaokao(),
        }
    }

    fn serve(
        &self,
        trace: &[Request],
        stream: bool,
        preempt: bool,
        audit: bool,
    ) -> Result<sart::coordinator::ServeResult, String> {
        let mut engine = SimEngine::new(
            self.slots,
            512,
            self.spec.clone(),
            SimCostModel::default(),
        );
        engine.set_prompt_bucket(256);
        let mut prm = OraclePrm::new(0.1, self.seed ^ 7);
        let cfg = SchedConfig {
            policy: self.policy,
            t_round: self.t_round,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(self.kv_tokens, 16)
                .with_prefix_cache(self.prefix_cache_pages)
                .with_chunked_prefill(self.chunk, self.budget)
                .with_stream_admission(stream)
                .with_preemption(preempt),
            adaptive: None,
            seed: self.seed,
        };
        let mut sched = Scheduler::new(
            cfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(audit);
        sched
            .serve(trace)
            .map_err(|e| format!("stream={stream} preempt={preempt}: {e}"))
    }
}

#[test]
fn prop_pressure_knobs_without_pressure_are_byte_identical() {
    // ISSUE 9 acceptance: stream admission + preemption enabled under a
    // budget that never defers an admission must reproduce the knobs-off
    // serve exactly — outcomes, timeline and round count, audit on. This
    // pins the whole pressure machinery (first-chunk pledges, per-chunk
    // pledge growth, priority bookkeeping, the head-of-line stall gate)
    // to a provable no-op until an admission actually defers.
    check("pressure_noop_identity", 10, |rng| {
        let n_req = 4 + rng.below(10);
        let case = Case::random(rng, n_req, true);
        let rate = 0.5 + 4.0 * rng.f64();
        let share = 0.4 * rng.f64() + 0.4;
        let trace = templated_trace(
            &case.spec, n_req, rate, case.seed, share, 2, 3,
        );
        let off = case.serve(&trace, false, false, true)?;
        let on = case.serve(&trace, true, true, true)?;
        prop_assert!(
            off.rounds == on.rounds,
            "round count differs: {} vs {}",
            off.rounds,
            on.rounds
        );
        prop_assert!(off.outcomes == on.outcomes, "outcomes differ");
        prop_assert!(
            off.timeline.points == on.timeline.points,
            "timeline differs"
        );
        prop_assert!(
            on.outcomes.iter().all(|o| o.preemptions == 0),
            "preempted without pressure"
        );
        Ok(())
    });
}

#[test]
fn prop_pressure_knobs_identity_holds_at_cluster_scale() {
    // Same no-op contract through the cluster dispatcher at R = 2:
    // routing, per-replica serving and the merged outcomes must all be
    // unaffected (kv pressure feeds the scale controller, which is off
    // here; routing never reads pledges). Audit on in every replica.
    check("pressure_cluster_identity", 6, |rng| {
        let n_req = 6 + rng.below(8);
        let case = Case::random(rng, n_req, true);
        let trace = templated_trace(
            &case.spec,
            n_req,
            0.5 + 4.0 * rng.f64(),
            case.seed,
            0.8,
            2,
            3,
        );
        let serve = |stream: bool, preempt: bool| {
            let mut engines: Vec<Box<dyn Engine>> = (0..2)
                .map(|_| {
                    let mut e = SimEngine::new(
                        case.slots,
                        512,
                        case.spec.clone(),
                        SimCostModel::default(),
                    );
                    e.set_prompt_bucket(256);
                    Box::new(e) as Box<dyn Engine>
                })
                .collect();
            let mut prms: Vec<Box<dyn PrmScorer>> = (0..2u64)
                .map(|i| {
                    Box::new(OraclePrm::new(0.1, case.seed ^ 7 ^ (i << 32)))
                        as Box<dyn PrmScorer>
                })
                .collect();
            let ccfg = ClusterConfig {
                replicas: 2,
                lb: LbPolicy::PrefixAffinity,
                sched: SchedConfig {
                    policy: case.policy,
                    t_round: case.t_round,
                    temperature: 1.0,
                    max_new: 224,
                    kv: KvConfig::new(case.kv_tokens, 16)
                        .with_prefix_cache(case.prefix_cache_pages)
                        .with_chunked_prefill(case.chunk, case.budget)
                        .with_stream_admission(stream)
                        .with_preemption(preempt),
                    adaptive: None,
                    seed: case.seed,
                },
                seed: case.seed,
                audit: true,
                gossip_rounds: 0,
                gossip_adapt: false,
                fault_plan: Default::default(),
                scale: None,
            };
            serve_cluster(&ccfg, &mut engines, &mut prms, &trace)
                .map_err(|e| format!("stream={stream}: {e}"))
        };
        let off = serve(false, false)?;
        let on = serve(true, true)?;
        prop_assert!(off.outcomes == on.outcomes, "merged outcomes differ");
        prop_assert!(
            off.assignments == on.assignments,
            "routing decisions differ"
        );
        for (r_off, r_on) in
            off.replica_results.iter().zip(&on.replica_results)
        {
            prop_assert!(
                r_off.timeline.points == r_on.timeline.points,
                "a replica timeline differs"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tight_budget_pressure_serve_is_audited_and_loses_nothing() {
    // A genuinely tight budget (barely one full request admissible) with
    // both knobs on: every request is still served, the fast path stays
    // byte-identical to audit mode (which rebuilds the grown-pledge and
    // priority structures from scratch every round), the prefill backlog
    // drains, and per-request times stay ordered. Preemption may or may
    // not fire depending on the sampled policy — only pruning policies
    // score running branches — which is exactly the contract.
    check("pressure_tight_budget", 10, |rng| {
        let n_req = 4 + rng.below(8);
        let case = Case::random(rng, n_req, false);
        let trace = templated_trace(
            &case.spec,
            n_req,
            0.5 + 4.0 * rng.f64(),
            case.seed,
            0.8,
            2,
            3,
        );
        let fast = case.serve(&trace, true, true, false)?;
        let audited = case.serve(&trace, true, true, true)?;
        prop_assert!(fast.outcomes == audited.outcomes, "outcomes differ");
        prop_assert!(
            fast.timeline.points == audited.timeline.points,
            "timeline differs"
        );
        prop_assert!(
            fast.outcomes.len() == n_req,
            "lost requests: {} of {n_req}",
            fast.outcomes.len()
        );
        for o in &fast.outcomes {
            prop_assert!(
                o.admitted_at <= o.prefill_done_at
                    && o.prefill_done_at <= o.finished_at,
                "TTFT split out of order for request {}",
                o.id
            );
        }
        let last = fast.timeline.points.last().ok_or("empty timeline")?;
        prop_assert!(
            last.queued_prefill_tokens == 0,
            "prefill backlog not drained: {}",
            last.queued_prefill_tokens
        );
        Ok(())
    });
}

#[test]
fn preemption_swaps_out_low_reward_branches_to_admit_the_blocked_request() {
    // Deterministic regression for the swap-out/recompute cycle. Two
    // batch arrivals; the budget fits request 0 (4 branches) whole and
    // leaves request 1 short by ~2 branch reservations. With preemption
    // on, the manager must reclaim request 0's lowest-reward branches
    // (it keeps >= 1 kv holder, so the prefix lease survives), admit
    // request 1 on the retry, and later resume the victims by
    // recomputation — with preemption off, request 1 can only wait for
    // request 0's branches to finish. Sart (a pruning policy) is
    // required: only scored running branches enter the candidate pool.
    let spec = TaskSpec::synth_gaokao();
    let trace = batch_trace(&spec, 2, 17);
    let pages_for = |t: usize| t.div_ceil(16);
    let pa = pages_for(trace[0].prompt_tokens().len());
    let pb = pages_for(trace[1].prompt_tokens().len());
    // 4 branches x pages_for(224) = 14 pages each. Request 0 fits whole;
    // request 1's deficit (26 pages) is covered by preempting 2 of
    // request 0's branches (28 pages).
    let cap_pages = pa + 4 * 14 + pb + 30;
    let serve = |preempt: bool| {
        let mut engine =
            SimEngine::new(8, 512, spec.clone(), SimCostModel::default());
        engine.set_prompt_bucket(256);
        let mut prm = OraclePrm::new(0.1, 17 ^ 7);
        let cfg = SchedConfig {
            policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(16 * cap_pages, 16).with_preemption(preempt),
            adaptive: None,
            seed: 17,
        };
        let mut sched = Scheduler::new(
            cfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(true);
        sched.serve(&trace).expect("pressure serve")
    };
    let on = serve(true);
    let off = serve(false);
    assert_eq!(on.outcomes.len(), 2, "lost a request with preemption on");
    assert_eq!(off.outcomes.len(), 2, "lost a request with preemption off");

    let preempted: usize = on.outcomes.iter().map(|o| o.preemptions).sum();
    assert!(
        preempted >= 1,
        "the tight budget must force at least one swap-out"
    );
    assert!(
        off.outcomes.iter().all(|o| o.preemptions == 0),
        "preemptions recorded with the knob off"
    );
    // The swap-outs land on the already-admitted request, not the one
    // they let in.
    let on_a = on.outcomes.iter().find(|o| o.id == 0).unwrap();
    let on_b = on.outcomes.iter().find(|o| o.id == 1).unwrap();
    assert!(on_a.preemptions >= 1, "victim request recorded no swap-out");
    assert_eq!(on_b.preemptions, 0, "the admitted request was preempted");
    // Reclaiming pages admits request 1 strictly earlier than waiting
    // for request 0's branches to finish.
    let off_b = off.outcomes.iter().find(|o| o.id == 1).unwrap();
    assert!(
        on_b.admitted_at < off_b.admitted_at,
        "preemption did not accelerate admission: {} vs {}",
        on_b.admitted_at,
        off_b.admitted_at
    );
    // Recompute-on-resume kept both requests alive to completion.
    for o in &on.outcomes {
        assert!(
            o.tokens_generated > 0 && o.finished_at >= o.admitted_at,
            "request {} did not finish cleanly after the swap-outs",
            o.id
        );
    }
}

#[test]
fn kv_invariants_rebuild_streamed_pledges_and_priorities() {
    // Drive the manager through a hand-rolled stream — first-chunk
    // admission, per-chunk pledge growth, staged progress, commit,
    // priorities — calling `check_invariants` (the audit-mode rebuild of
    // the grown-pledge and priority structures) at every step.
    let mut kv = KvCacheManager::with_prefix_cache(16 * 256, 16, 16);
    let prompt: Vec<i32> = (0..160).collect();
    let adm = kv
        .admit(&AdmissionRequest::streamed(&prompt, 64, 2, 32))
        .unwrap()
        .into_admission()
        .unwrap();
    kv.check_invariants().expect("after streamed admission");
    assert!(kv.pledged_pages() > 0, "first chunk was not pledged");

    let mut fed = 0;
    while fed < prompt.len() {
        let chunk = 32.min(prompt.len() - fed);
        assert!(
            kv.ensure_pledged(adm.prefix, chunk).unwrap(),
            "a generous budget must always grow the pledge"
        );
        kv.note_prefill(adm.prefix, chunk).unwrap();
        fed += chunk;
        kv.check_invariants().expect("mid-stream");
    }
    kv.commit_prefix(adm.prefix, &prompt).unwrap();
    kv.check_invariants().expect("after commit");
    assert_eq!(kv.pledged_pages(), 0, "commit left a dangling pledge");

    // Priorities: the rebuilt preemptable pool must track them exactly,
    // and candidates rank lowest reward first.
    for (i, &b) in adm.branches.iter().enumerate() {
        kv.set_branch_priority(b, 0.25 * i as f32).unwrap();
        kv.note_decode(b, 3).unwrap();
    }
    kv.check_invariants().expect("with priorities");
    assert!(kv.preemptable_pages() > 0, "scored branches not preemptable");
    let ranked = kv.preemption_candidates(1);
    assert_eq!(
        ranked.first().copied(),
        Some(adm.branches[0]),
        "lowest-reward branch must rank first"
    );
    for b in adm.branches {
        kv.release_branch(b).unwrap();
    }
    kv.check_invariants().expect("after release");
    assert_eq!(kv.preemptable_pages(), 0, "released branch still pooled");

    // A stream whose total footprint exceeds the whole budget must be
    // deferred outright (it could never finish), even though its first
    // chunk fits — the rule that keeps mid-prompt stalls transient.
    let out = kv
        .admit(&AdmissionRequest::streamed(&prompt, 1 << 20, 1, 32))
        .unwrap();
    match out {
        AdmissionOutcome::Deferred { need_pages, .. } => {
            assert!(need_pages > 256, "deferral must report the full need");
        }
        AdmissionOutcome::Admitted(_) => {
            panic!("oversized stream admitted on its first chunk")
        }
    }
    kv.check_invariants().expect("deferral must be side-effect free");
}
