//! Chunked prefill: decode-overlap scheduling tests.
//!
//! The contract (ISSUE 4):
//!
//! * `prefill_chunk_tokens = 0` is the historical monolithic behaviour —
//!   and the degenerate chunked configuration (chunk larger than any
//!   suffix, unlimited budget) is *byte-identical* to it with the prefix
//!   cache off: same outcomes, same timeline, same round count, audit on
//!   (the property below). With the cache on the two modes legitimately
//!   differ only in interning time: chunked admission interns a prompt
//!   at prefill completion, monolithic at admission, so two same-header
//!   requests admitted in one round see different hits.
//! * With real chunking (small chunks, a per-round budget), audited and
//!   fast serves stay byte-identical, every request is served, the
//!   queued-prefill backlog drains, and the TTFT split is ordered.
//! * A long cold few-shot header must stream across rounds while
//!   resident branches keep decoding — and the worst per-round decode
//!   stall (prefill seconds absorbed by a round with resident branches)
//!   must be strictly smaller than under monolithic prefill.

use sart::coordinator::{ClockHandle, KvConfig, Policy, SchedConfig, Scheduler};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::metrics::Timeline;
use sart::prm::OraclePrm;
use sart::prop_assert;
use sart::testkit::check;
use sart::util::clock::SimClock;
use sart::util::rng::Rng;
use sart::workload::{templated_trace, Request, TaskSpec};

fn random_policy(rng: &mut Rng) -> Policy {
    let n = 1 << rng.below(4); // 1,2,4,8
    match rng.below(4) {
        0 => Policy::Vanilla,
        1 => Policy::SelfConsistency { n },
        2 => Policy::SartNoPrune { n, m: (n / 2).max(1) },
        _ => Policy::Sart {
            n,
            m: (n / 2).max(1),
            alpha: (0.3 + 0.4 * rng.f64()) as f32,
            beta: (n / 2).max(1),
        },
    }
}

/// One serve configuration; `chunk`/`budget` vary per run.
struct Case {
    policy: Policy,
    slots: usize,
    t_round: usize,
    kv_tokens: usize,
    prefix_cache_pages: usize,
    seed: u64,
    spec: TaskSpec,
}

impl Case {
    fn random(rng: &mut Rng, prefix_cache_pages: usize) -> Case {
        let policy = random_policy(rng);
        // Headered prompts reach ~11 pages; always keep one full request
        // admissible so the serve cannot stall.
        let min_pages = 11 + policy.n_branches() * 14 + 4;
        Case {
            policy,
            slots: 2 + rng.below(14),
            t_round: 8 + rng.below(24),
            kv_tokens: 16 * (min_pages + rng.below(1024)),
            prefix_cache_pages,
            seed: rng.next_u64(),
            spec: TaskSpec::synth_gaokao(),
        }
    }

    fn serve(
        &self,
        trace: &[Request],
        chunk: usize,
        budget: usize,
        audit: bool,
    ) -> Result<sart::coordinator::ServeResult, String> {
        let mut engine = SimEngine::new(
            self.slots,
            512,
            self.spec.clone(),
            SimCostModel::default(),
        );
        engine.set_prompt_bucket(256);
        let mut prm = OraclePrm::new(0.1, self.seed ^ 7);
        let cfg = SchedConfig {
            policy: self.policy,
            t_round: self.t_round,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(self.kv_tokens, 16)
                .with_prefix_cache(self.prefix_cache_pages)
                .with_chunked_prefill(chunk, budget),
            adaptive: None,
            seed: self.seed,
        };
        let mut sched = Scheduler::new(
            cfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(audit);
        sched
            .serve(trace)
            .map_err(|e| format!("chunk={chunk} budget={budget}: {e}"))
    }
}

#[test]
fn prop_degenerate_chunking_is_byte_identical_to_monolithic() {
    // ISSUE 4 acceptance: chunk-larger-than-any-suffix + unlimited budget
    // must reproduce `prefill_chunk_tokens = 0` exactly — outcomes,
    // timeline (including the new queued-prefill / prefill-seconds
    // fields) and round count — audit on. This pins the whole streaming
    // machinery (cursors, pledged kv pages, install-only entries) to the
    // monolithic semantics in the limit.
    //
    // Two scopings keep the comparison exact rather than approximate:
    // the cache stays off (chunked admission interns at completion,
    // monolithic at admission — two same-header requests admitted in one
    // round would legitimately see different hits), and same-round
    // sibling starts are excluded (N = 1, or a single slot) because a
    // sibling physically cannot fork from a prefix whose completing
    // chunk lands later in the same round — monolithic prefill pretends
    // it can. Multi-branch multi-slot chunked serving is pinned by the
    // audit-identity property below instead.
    check("chunked_degenerate_identity", 10, |rng| {
        let mut case = Case::random(rng, 0);
        if rng.chance(0.5) {
            case.policy = Policy::Vanilla;
        } else {
            case.slots = 1;
        }
        let n_req = 4 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let share = 0.4 * rng.f64() + 0.4;
        let trace = templated_trace(
            &case.spec, n_req, rate, case.seed, share, 2, 2,
        );
        let mono = case.serve(&trace, 0, 0, true)?;
        let degen = case.serve(&trace, 4096, 0, true)?;
        prop_assert!(
            mono.rounds == degen.rounds,
            "round count differs: {} vs {}",
            mono.rounds,
            degen.rounds
        );
        prop_assert!(mono.outcomes == degen.outcomes, "outcomes differ");
        prop_assert!(
            mono.timeline.points == degen.timeline.points,
            "timeline differs"
        );
        Ok(())
    });
}

#[test]
fn prop_chunked_serve_audit_identical_and_drains() {
    // Real chunking (small chunks, per-round budget), cache on or off:
    // audit-mode recomputation of the chunk structures must agree with
    // the fast path byte for byte, every request is served, the prefill
    // backlog fully drains, and per-request times are ordered.
    check("chunked_audit_identity", 10, |rng| {
        let cache = if rng.chance(0.5) { 0 } else { 4 + rng.below(64) };
        let case = Case::random(rng, cache);
        let n_req = 4 + rng.below(10);
        let rate = 0.5 + 4.0 * rng.f64();
        let chunk = 8 + rng.below(48);
        let budget = chunk * (1 + rng.below(4));
        let trace = templated_trace(
            &case.spec, n_req, rate, case.seed, 0.8, 2, 3,
        );
        let fast = case.serve(&trace, chunk, budget, false)?;
        let audited = case.serve(&trace, chunk, budget, true)?;
        prop_assert!(fast.outcomes == audited.outcomes, "outcomes differ");
        prop_assert!(
            fast.timeline.points == audited.timeline.points,
            "timeline differs"
        );
        prop_assert!(fast.outcomes.len() == n_req, "lost requests");
        for o in &fast.outcomes {
            prop_assert!(
                o.admitted_at <= o.prefill_done_at
                    && o.prefill_done_at <= o.finished_at,
                "TTFT split out of order for request {}",
                o.id
            );
        }
        let last = fast.timeline.points.last().ok_or("empty timeline")?;
        prop_assert!(
            last.queued_prefill_tokens == 0,
            "prefill backlog not drained: {}",
            last.queued_prefill_tokens
        );
        let mut prev = 0.0f64;
        for p in &fast.timeline.points {
            prop_assert!(
                p.prefill_seconds >= prev,
                "cumulative prefill seconds decreased"
            );
            prev = p.prefill_seconds;
        }
        Ok(())
    });
}

/// Worst per-round decode stall (the stall definition itself lives in
/// `Timeline::decode_stall_series`, shared with the chunked bench).
fn max_stall(tl: &Timeline) -> f64 {
    tl.decode_stall_series().into_iter().fold(0.0f64, f64::max)
}

#[test]
fn long_cold_headers_overlap_decode_and_cut_worst_round_stall() {
    // Deterministic: a prefix-heavy trace with long cold few-shot
    // headers (many templates, no cache → every header is cold) under a
    // token-priced prefill cost model. Monolithic prefill swallows a
    // whole header in one round — every resident branch stalls for it.
    // Chunked prefill bounds the per-round prefill work, so the worst
    // round stall must drop strictly, while decode keeps making progress
    // in rounds that still carry a prefill backlog.
    let spec = TaskSpec::synth_gaokao();
    let trace = templated_trace(&spec, 48, 3.0, 11, 1.0, 6, 5);
    let serve = |chunk: usize, budget: usize| {
        // 5-shot gaokao headers reach ~240 tokens (+27-token question),
        // so the advisory bucket must exceed the default 256.
        let mut engine = SimEngine::new(
            8,
            560,
            spec.clone(),
            SimCostModel {
                prefill_per_token: 0.2e-3,
                ..SimCostModel::default()
            },
        );
        engine.set_prompt_bucket(288);
        let mut prm = OraclePrm::new(0.08, 11 ^ 7);
        let cfg = SchedConfig {
            policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(32768, 16)
                .with_chunked_prefill(chunk, budget),
            adaptive: None,
            seed: 11,
        };
        let mut sched = Scheduler::new(
            cfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(true);
        sched.serve(&trace).expect("chunked stall serve")
    };
    let mono = serve(0, 0);
    let chunked = serve(32, 32);
    assert_eq!(mono.outcomes.len(), 48);
    assert_eq!(chunked.outcomes.len(), 48);

    // Decode overlaps the streaming: some round both carries a prefill
    // backlog and grows the decoded-token count.
    let overlapped = chunked.timeline.points.windows(2).any(|w| {
        w[1].queued_prefill_tokens > 0
            && w[1].running_tokens > w[0].running_tokens
    });
    assert!(overlapped, "no round decoded while a header streamed");
    assert!(
        mono.timeline
            .points
            .iter()
            .all(|p| p.queued_prefill_tokens == 0),
        "monolithic serve must never queue prefill"
    );

    let stall_mono = max_stall(&mono.timeline);
    let stall_chunked = max_stall(&chunked.timeline);
    assert!(
        stall_chunked < stall_mono,
        "worst round stall must drop: chunked {stall_chunked:.4}s vs \
         mono {stall_mono:.4}s"
    );

    // Sibling branches fork from the streamed prefix without re-paying
    // it: SART N=4 requests start more than one branch.
    assert!(
        chunked
            .outcomes
            .iter()
            .any(|o| o.branches_started > 1),
        "no sibling ever started under chunking"
    );
}

#[test]
fn warm_headers_skip_streaming_under_cache() {
    // Cache on, one hot template: after the first request interns the
    // header (at commit time), later admissions only stream their short
    // question suffix — the backlog must collapse accordingly, and the
    // cache must report hits exactly as in the monolithic path.
    let spec = TaskSpec::synth_gaokao();
    let trace = templated_trace(&spec, 24, 1.0, 9, 1.0, 1, 4);
    let serve = |chunk: usize| {
        let mut engine = SimEngine::new(
            8,
            512,
            spec.clone(),
            SimCostModel::default(),
        );
        engine.set_prompt_bucket(256);
        let mut prm = OraclePrm::new(0.08, 9 ^ 7);
        let cfg = SchedConfig {
            policy: Policy::Sart { n: 2, m: 1, alpha: 0.5, beta: 1 },
            t_round: 16,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(32768, 16)
                .with_prefix_cache(64)
                .with_chunked_prefill(chunk, chunk),
            adaptive: None,
            seed: 9,
        };
        let mut sched = Scheduler::new(
            cfg,
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        sched.set_audit(true);
        sched.serve(&trace).expect("warm chunked serve")
    };
    let res = serve(24);
    assert_eq!(res.outcomes.len(), 24);
    assert!(res.prompt_tokens > 0);
    let saved = res.cache_hit_tokens as f64 / res.prompt_tokens as f64;
    assert!(
        saved > 0.3,
        "warm chunked serve saved only {saved:.3} of prompt tokens"
    );
    // The cold header dominates the backlog high-water mark; warm
    // requests stream a < 2-page question suffix at most.
    let peak = res
        .timeline
        .points
        .iter()
        .map(|p| p.queued_prefill_tokens)
        .max()
        .unwrap_or(0);
    assert!(peak > 100, "cold header never queued ({peak} tokens)");
}
