//! Property-based tests over system invariants (via `sart::testkit`,
//! the in-repo stand-in for proptest — see DESIGN.md §2).

use sart::cluster::{
    serve_cluster, serve_cluster_with, ClusterConfig, LbPolicy,
    REPLICA_SEED_STRIDE,
};
use sart::coordinator::{
    ClockHandle, KvConfig, Policy, SchedConfig, Scheduler, ServeEvent,
};
use sart::engine::sim::{SimCostModel, SimEngine};
use sart::engine::Engine;
use sart::kvcache::{AdmissionOutcome, AdmissionRequest, KvCacheManager};
use sart::prm::{OraclePrm, PrmScorer};
use sart::prop_assert;
use sart::testkit::{check, default_cases};
use sart::tokenizer as tok;
use sart::util::clock::SimClock;
use sart::util::rng::Rng;
use sart::util::stats::percentile;
use sart::workload::{poisson_trace, Question, TaskSpec};

// ---------------------------------------------------------------------------
// KV-cache manager invariants under random admit/release interleavings.
// ---------------------------------------------------------------------------

#[test]
fn prop_kvcache_accounting_never_drifts() {
    check("kvcache_accounting", default_cases(), |rng| {
        let page = 1 + rng.below(32);
        let cap_pages = 8 + rng.below(128);
        let mut kv = KvCacheManager::new(cap_pages * page, page);
        let mut live: Vec<sart::kvcache::BranchId> = Vec::new();
        for _ in 0..200 {
            if rng.chance(0.5) && !live.is_empty() {
                let i = rng.below(live.len());
                let b = live.swap_remove(i);
                kv.release_branch(b).map_err(|e| e.to_string())?;
            } else {
                let plen = 1 + rng.below(64);
                let max_new = 1 + rng.below(256);
                let n = 1 + rng.below(8);
                let prompt: Vec<tok::Token> =
                    (0..plen as tok::Token).collect();
                let req = AdmissionRequest::monolithic(&prompt, max_new, n);
                match kv.admit(&req).map_err(|e| e.to_string())? {
                    AdmissionOutcome::Admitted(adm) => {
                        live.extend(adm.branches);
                    }
                    AdmissionOutcome::Deferred { need_pages, free_pages } => {
                        // Deferred must be honestly sized and
                        // side-effect free: an immediate retry defers
                        // again with the same shortfall.
                        prop_assert!(
                            need_pages > free_pages,
                            "deferred but {need_pages} <= {free_pages}"
                        );
                        prop_assert!(
                            kv.admit(&req)
                                .map_err(|e| e.to_string())?
                                .is_deferred(),
                            "retry admitted after a deferral \
                             (deferral had side effects)"
                        );
                    }
                }
            }
            kv.check_invariants().map_err(|e| e.to_string())?;
            prop_assert!(
                kv.used_pages() <= kv.capacity_pages(),
                "over budget: {} > {}",
                kv.used_pages(),
                kv.capacity_pages()
            );
        }
        // Drain: releasing everything must return to exactly zero.
        for b in live.drain(..) {
            kv.release_branch(b).map_err(|e| e.to_string())?;
        }
        prop_assert!(kv.used_pages() == 0, "leak: {} pages", kv.used_pages());
        prop_assert!(kv.live_prefixes() == 0, "prefix leak");
        Ok(())
    });
}

#[test]
fn prop_kvcache_grow_shares_prefix() {
    check("kvcache_grow", default_cases(), |rng| {
        let mut kv = KvCacheManager::new(64 * 16, 16);
        let p: Vec<tok::Token> = (0..30).collect();
        let adm = kv
            .admit(&AdmissionRequest::monolithic(&p, 60, 2))
            .map_err(|e| e.to_string())?
            .into_admission()
            .map_err(|e| e.to_string())?;
        let prefix = adm.prefix;
        let mut bs = adm.branches;
        let before = kv.used_pages();
        let more = 1 + rng.below(3);
        if let AdmissionOutcome::Admitted(grown) = kv
            .admit(&AdmissionRequest::grow(prefix, 60, more))
            .map_err(|e| e.to_string())?
        {
            // Grow adds only branch pages (ceil(60/16)=4), no prefix pages.
            prop_assert!(
                kv.used_pages() == before + more * 4,
                "grow page math wrong"
            );
            bs.extend(grown.branches);
        }
        for b in bs {
            kv.release_branch(b).map_err(|e| e.to_string())?;
        }
        prop_assert!(kv.used_pages() == 0, "leak after grow+release");
        kv.check_invariants().map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_kv_cache_disabled_admission_is_content_blind() {
    // With a zero prefix-cache budget, monolithic admission must be the
    // pre-cache scalar accounting: prompt *content* cannot matter, only
    // length. Two managers fed same-length prompts — one a constant
    // header repeated every step (maximum sharing opportunity), one
    // unique per step — must make identical admission decisions with
    // identical page accounting and zero reported hits.
    check("kv_cache_disabled_scalar", default_cases(), |rng| {
        let page = 1 + rng.below(32);
        let cap_pages = 8 + rng.below(128);
        let mut scalar = KvCacheManager::new(cap_pages * page, page);
        let mut tokens = KvCacheManager::new(cap_pages * page, page);
        let mut live_s: Vec<sart::kvcache::BranchId> = Vec::new();
        let mut live_t: Vec<sart::kvcache::BranchId> = Vec::new();
        for step in 0..150usize {
            if rng.chance(0.5) && !live_s.is_empty() {
                let i = rng.below(live_s.len());
                let s = live_s.swap_remove(i);
                let t = live_t.swap_remove(i);
                scalar.release_branch(s).map_err(|e| e.to_string())?;
                tokens.release_branch(t).map_err(|e| e.to_string())?;
            } else {
                let plen = 1 + rng.below(64);
                let max_new = 1 + rng.below(256);
                let n = 1 + rng.below(8);
                let constant: Vec<tok::Token> = vec![7; plen];
                let unique: Vec<tok::Token> =
                    (0..plen).map(|t| (step * 100 + t) as tok::Token).collect();
                let out_s = scalar
                    .admit(&AdmissionRequest::monolithic(&constant, max_new, n))
                    .map_err(|e| e.to_string())?;
                let out_t = tokens
                    .admit(&AdmissionRequest::monolithic(&unique, max_new, n))
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    out_s.is_deferred() == out_t.is_deferred(),
                    "admission decision diverged on prompt content"
                );
                if let (
                    AdmissionOutcome::Admitted(a),
                    AdmissionOutcome::Admitted(b),
                ) = (out_s, out_t)
                {
                    prop_assert!(
                        a.cached_tokens == 0 && b.cached_tokens == 0,
                        "cache-disabled admit reported a hit"
                    );
                    live_s.extend(a.branches);
                    live_t.extend(b.branches);
                }
            }
            prop_assert!(
                scalar.used_pages() == tokens.used_pages()
                    && scalar.free_pages() == tokens.free_pages(),
                "page accounting diverged: {} vs {}",
                scalar.used_pages(),
                tokens.used_pages()
            );
            prop_assert!(
                tokens.cached_pages() == 0,
                "cache-disabled manager retained pages"
            );
            tokens.check_invariants().map_err(|e| e.to_string())?;
        }
        prop_assert!(
            tokens.cache_hit_tokens_total() == 0,
            "cache-disabled manager counted hits"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Scheduler invariants over random workloads/policies (SimEngine).
// ---------------------------------------------------------------------------

fn random_policy(rng: &mut Rng) -> Policy {
    let n = 1 << rng.below(4); // 1,2,4,8
    match rng.below(4) {
        0 => Policy::Vanilla,
        1 => Policy::SelfConsistency { n },
        2 => Policy::SartNoPrune { n, m: (n / 2).max(1) },
        _ => Policy::Sart {
            n,
            m: (n / 2).max(1),
            alpha: (0.3 + 0.4 * rng.f64()) as f32,
            beta: (n / 2).max(1),
        },
    }
}

#[test]
fn prop_scheduler_serves_every_request_exactly_once() {
    check("scheduler_serves_all", 24, |rng| {
        let policy = random_policy(rng);
        let slots = 2 + rng.below(14);
        let n_req = 4 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let spec = if rng.chance(0.5) {
            TaskSpec::synth_gaokao()
        } else {
            TaskSpec::synth_gpqa()
        };
        let seed = rng.next_u64();
        let trace = poisson_trace(&spec, n_req, rate, seed);
        let mut engine = SimEngine::new(slots, 256, spec,
                                        SimCostModel::default());
        let mut prm = OraclePrm::new(0.1, seed ^ 7);
        let cfg = SchedConfig {
            policy,
            t_round: 8 + rng.below(24),
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(16 * (64 + rng.below(1024)), 16),
            adaptive: None,
            seed,
        };
        let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                       ClockHandle::Sim(SimClock::new()));
        let res = sched.serve(&trace).map_err(|e| e.to_string())?;
        prop_assert!(res.outcomes.len() == n_req, "lost requests");
        let n = policy.n_branches();
        for o in &res.outcomes {
            prop_assert!(o.finished_at >= o.arrival, "finished before arrival");
            prop_assert!(o.admitted_at >= o.arrival, "admitted before arrival");
            prop_assert!(o.finished_at >= o.admitted_at, "negative inference");
            prop_assert!(o.branches_started <= n, "started more than N");
            prop_assert!(
                o.branches_completed + o.branches_pruned <= n,
                "completed+pruned {} + {} > N {}",
                o.branches_completed,
                o.branches_pruned,
                n
            );
            // branches_completed counts only answer-bearing harvests (the
            // early-stop quorum); a request whose every branch capped
            // without an answer can legitimately finalize with zero — but
            // it must always have harvested *something* to vote over.
            prop_assert!(
                !o.response_lengths.is_empty(),
                "finalized with nothing harvested"
            );
            prop_assert!(
                o.branches_completed <= o.response_lengths.len(),
                "more answered than harvested"
            );
        }
        // Timeline occupancy can never exceed slot count.
        for p in &res.timeline.points {
            prop_assert!(p.running_branches <= slots, "slot overflow");
        }
        Ok(())
    });
}

#[test]
fn prop_early_stopping_dominates_waiting_for_all() {
    // For the same workload and seed, SART-no-prune (M=N/2) must finish
    // requests no later on average than Self-Consistency (M=N) — Lemma 1's
    // operational consequence. Asserted on the mean to avoid per-request
    // scheduling ties.
    check("early_stop_dominates", 12, |rng| {
        let n = 4 + 4 * rng.below(2); // 4 or 8
        let seed = rng.next_u64();
        let spec = TaskSpec::synth_gaokao();
        let trace = poisson_trace(&spec, 10, 2.0, seed);
        let mut run = |policy: Policy| -> Result<f64, String> {
            let mut engine = SimEngine::new(8, 256, spec.clone(),
                                            SimCostModel::default());
            let mut prm = OraclePrm::new(0.1, seed);
            let cfg = SchedConfig {
                policy,
                t_round: 16,
                temperature: 1.0,
                max_new: 224,
                kv: KvConfig::new(16384, 16),
                adaptive: None,
                seed,
            };
            let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                           ClockHandle::Sim(SimClock::new()));
            let res = sched.serve(&trace).map_err(|e| e.to_string())?;
            Ok(res
                .outcomes
                .iter()
                .map(|o| o.e2e_latency())
                .sum::<f64>()
                / res.outcomes.len() as f64)
        };
        let sc = run(Policy::SelfConsistency { n })?;
        let es = run(Policy::SartNoPrune { n, m: n / 2 })?;
        prop_assert!(
            es <= sc * 1.05,
            "early stopping slower than waiting: {es} > {sc}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Incremental bookkeeping vs from-scratch recomputation.
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_audit_matches_fast_path() {
    // Audit mode recomputes every incremental structure (slot freelist,
    // per-request running-branch index, running_tokens, cached prompts,
    // kv counters) from straightforward full scans each round and errors
    // on any drift. It must not change behaviour either: the audited and
    // fast serves must be byte-identical (same outcomes, same timeline).
    check("sched_audit", 10, |rng| {
        let policy = random_policy(rng);
        let slots = 2 + rng.below(14);
        let n_req = 4 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let spec = if rng.chance(0.5) {
            TaskSpec::synth_gaokao()
        } else {
            TaskSpec::synth_gpqa()
        };
        let seed = rng.next_u64();
        let t_round = 8 + rng.below(24);
        // Budget always admits at least one full request (no stalls):
        // prompt 27 → 2 pages, plus N branches × ceil(224/16) pages.
        let min_pages = 2 + policy.n_branches() * 14 + 4;
        let kv_tokens = 16 * (min_pages + rng.below(1024));
        let trace = poisson_trace(&spec, n_req, rate, seed);
        let mut run = |audit: bool| {
            let mut engine = SimEngine::new(slots, 256, spec.clone(),
                                            SimCostModel::default());
            let mut prm = OraclePrm::new(0.1, seed ^ 7);
            let cfg = SchedConfig {
                policy,
                t_round,
                temperature: 1.0,
                max_new: 224,
                kv: KvConfig::new(kv_tokens, 16),
                adaptive: None,
                seed,
            };
            let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                           ClockHandle::Sim(SimClock::new()));
            sched.set_audit(audit);
            sched.serve(&trace).map_err(|e| format!("audit={audit}: {e}"))
        };
        let fast = run(false)?;
        let audited = run(true)?;
        prop_assert!(
            fast.rounds == audited.rounds,
            "round count differs: {} vs {}",
            fast.rounds,
            audited.rounds
        );
        prop_assert!(fast.outcomes == audited.outcomes, "outcomes differ");
        prop_assert!(
            fast.timeline.points == audited.timeline.points,
            "timeline differs"
        );
        Ok(())
    });
}

#[test]
fn prop_event_pump_serve_is_byte_identical() {
    // The wall-clock front end rests on this identity: `serve_with`
    // (emission on, every event forwarded to a sink as it happens) must
    // schedule byte-identically to the plain `serve` — same outcomes,
    // same timeline, same round count, audit on in both — and the event
    // stream must agree with the outcomes it narrates: exactly one
    // `Finalized` per request carrying the voted answer at the finish
    // instant, one `Admitted` at the admission instant, branch token
    // events summing to `tokens_generated`, pruned events matching
    // `branches_pruned`.
    check("event_pump_identity", 10, |rng| {
        let policy = random_policy(rng);
        let slots = 2 + rng.below(14);
        let n_req = 4 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let spec = if rng.chance(0.5) {
            TaskSpec::synth_gaokao()
        } else {
            TaskSpec::synth_gpqa()
        };
        let seed = rng.next_u64();
        let t_round = 8 + rng.below(24);
        // Budget always admits at least one full request (no stalls).
        let min_pages = 2 + policy.n_branches() * 14 + 4;
        let kv_tokens = 16 * (min_pages + rng.below(1024));
        let trace = poisson_trace(&spec, n_req, rate, seed);
        let mut run = |events: Option<&mut Vec<ServeEvent>>| {
            let mut engine = SimEngine::new(slots, 256, spec.clone(),
                                            SimCostModel::default());
            let mut prm = OraclePrm::new(0.1, seed ^ 7);
            let cfg = SchedConfig {
                policy,
                t_round,
                temperature: 1.0,
                max_new: 224,
                kv: KvConfig::new(kv_tokens, 16),
                adaptive: None,
                seed,
            };
            let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                           ClockHandle::Sim(SimClock::new()));
            sched.set_audit(true);
            match events {
                None => sched.serve(&trace),
                Some(evs) => {
                    sched.serve_with(&trace, &mut |ev| evs.push(ev))
                }
            }
            .map_err(|e| e.to_string())
        };
        let plain = run(None)?;
        let mut events: Vec<ServeEvent> = Vec::new();
        let pumped = run(Some(&mut events))?;
        prop_assert!(plain.outcomes == pumped.outcomes, "outcomes differ");
        prop_assert!(
            plain.timeline.points == pumped.timeline.points,
            "timeline differs"
        );
        prop_assert!(plain.rounds == pumped.rounds, "rounds differ");
        for o in &pumped.outcomes {
            let mine: Vec<ServeEvent> = events
                .iter()
                .filter(|e| e.request() == o.id)
                .cloned()
                .collect();
            let finals: Vec<(Option<u8>, usize, f64)> = mine
                .iter()
                .filter_map(|e| match e {
                    ServeEvent::Finalized { answer, votes, at, .. } => {
                        Some((*answer, *votes, *at))
                    }
                    _ => None,
                })
                .collect();
            prop_assert!(
                finals.len() == 1,
                "request {} finalized {} times",
                o.id,
                finals.len()
            );
            let (answer, votes, at) = finals[0];
            prop_assert!(answer == o.answer, "finalized answer diverges");
            prop_assert!(
                votes == o.response_lengths.len(),
                "vote count {votes} != {} harvested completions",
                o.response_lengths.len()
            );
            prop_assert!(at == o.finished_at, "finalized instant diverges");
            let admits: Vec<f64> = mine
                .iter()
                .filter_map(|e| match e {
                    ServeEvent::Admitted { at, .. } => Some(*at),
                    _ => None,
                })
                .collect();
            prop_assert!(
                admits == vec![o.admitted_at],
                "admitted events {admits:?} != [{}]",
                o.admitted_at
            );
            let streamed: usize = mine
                .iter()
                .map(|e| match e {
                    ServeEvent::BranchTokens { tokens, .. } => tokens.len(),
                    _ => 0,
                })
                .sum();
            prop_assert!(
                streamed == o.tokens_generated,
                "streamed {streamed} tokens != {} generated",
                o.tokens_generated
            );
            let pruned = mine
                .iter()
                .filter(|e| matches!(e, ServeEvent::BranchPruned { .. }))
                .count();
            prop_assert!(
                pruned == o.branches_pruned,
                "pruned events {pruned} != {}",
                o.branches_pruned
            );
        }
        Ok(())
    });
}

/// One prefix-heavy serve configuration (shared by the cache-neutrality
/// and cache-audit properties).
struct TemplatedCase {
    policy: Policy,
    slots: usize,
    t_round: usize,
    kv_tokens: usize,
    prefix_cache_pages: usize,
    seed: u64,
    spec: TaskSpec,
}

impl TemplatedCase {
    fn random(rng: &mut Rng, prefix_cache_pages: usize) -> TemplatedCase {
        let policy = random_policy(rng);
        // Headered prompts reach ~11 pages; always keep one full request
        // admissible so the serve cannot stall.
        let min_pages = 11 + policy.n_branches() * 14 + 4;
        TemplatedCase {
            policy,
            slots: 2 + rng.below(14),
            t_round: 8 + rng.below(24),
            kv_tokens: 16 * (min_pages + rng.below(1024)),
            prefix_cache_pages,
            seed: rng.next_u64(),
            spec: TaskSpec::synth_gaokao(),
        }
    }

    fn serve(
        &self,
        trace: &[sart::workload::Request],
        audit: bool,
    ) -> Result<sart::coordinator::ServeResult, String> {
        let mut engine = SimEngine::new(self.slots, 512, self.spec.clone(),
                                        SimCostModel::default());
        engine.set_prompt_bucket(256);
        let mut prm = OraclePrm::new(0.1, self.seed ^ 7);
        let cfg = SchedConfig {
            policy: self.policy,
            t_round: self.t_round,
            temperature: 1.0,
            max_new: 224,
            kv: KvConfig::new(self.kv_tokens, 16)
                .with_prefix_cache(self.prefix_cache_pages),
            adaptive: None,
            seed: self.seed,
        };
        let mut sched = Scheduler::new(cfg, &mut engine, &mut prm,
                                       ClockHandle::Sim(SimClock::new()));
        sched.set_audit(audit);
        sched.serve(trace).map_err(|e| {
            format!("cache={} audit={audit}: {e}", self.prefix_cache_pages)
        })
    }
}

#[test]
fn prop_cache_zero_serve_is_precache_identical() {
    // ISSUE 3 acceptance: with the cache capacity at 0, serves must be
    // byte-identical to the pre-cache behaviour across policies, audit
    // on. The pre-PR identity rests on three legs, each pinned here or
    // nearby: (1) admission delegates to the scalar path page-for-page
    // (prop_kv_cache_disabled_matches_scalar_admit); (2) the default
    // cost model prices prompt tokens at 0, i.e. the historical
    // flat-per-slot prefill cost — asserted below so a future nonzero
    // default (or any cost leak through cached_tokens) fails loudly
    // rather than silently shifting every cache-off timeline; (3) zero
    // hits are reported anywhere, fast and audited runs agreeing
    // byte-for-byte. Headered prompts are in play, so the prompt layout
    // matches the prefix-heavy workload exactly.
    assert_eq!(
        SimCostModel::default().prefill_per_token, 0.0,
        "default sim cost model must keep the pre-cache flat-per-slot \
         prefill pricing (cache-off serves are claimed byte-identical \
         to pre-PR)"
    );
    check("cache_zero_precache", 8, |rng| {
        let case = TemplatedCase::random(rng, 0);
        let n_req = 4 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let share = 0.3 + 0.6 * rng.f64();
        let trace = sart::workload::templated_trace(
            &case.spec, n_req, rate, case.seed, share, 2, 2,
        );
        let fast = case.serve(&trace, false)?;
        let audited = case.serve(&trace, true)?;
        prop_assert!(fast.outcomes == audited.outcomes, "outcomes differ");
        prop_assert!(
            fast.timeline.points == audited.timeline.points,
            "timeline differs"
        );
        prop_assert!(fast.rounds == audited.rounds, "rounds differ");
        prop_assert!(
            fast.cache_hit_tokens == 0 && audited.cache_hit_tokens == 0,
            "cache-disabled serve reported hits"
        );
        prop_assert!(
            fast.timeline.points.iter().all(|p| p.cache_hit_tokens == 0),
            "cache-disabled timeline recorded hits"
        );
        Ok(())
    });
}

#[test]
fn prop_cached_serve_audit_identical_and_consistent() {
    // With the cache ON (small budgets force LRU eviction mid-serve),
    // audit mode recomputes the radix refcounts / page accounting from
    // scratch every round; the audited and fast serves must still be
    // byte-identical, and the cumulative hit counter must be monotone
    // and consistent with the final result.
    check("cached_serve_audit", 8, |rng| {
        let cache_pages = 4 + rng.below(64); // small: eviction in play
        let case = TemplatedCase::random(rng, cache_pages);
        let n_req = 6 + rng.below(12);
        let rate = 0.5 + 4.0 * rng.f64();
        let trace = sart::workload::templated_trace(
            &case.spec, n_req, rate, case.seed, 0.8, 2, 2,
        );
        let fast = case.serve(&trace, false)?;
        let audited = case.serve(&trace, true)?;
        prop_assert!(fast.outcomes == audited.outcomes, "outcomes differ");
        prop_assert!(
            fast.timeline.points == audited.timeline.points,
            "timeline differs"
        );
        prop_assert!(
            fast.cache_hit_tokens == audited.cache_hit_tokens,
            "hit counters differ"
        );
        prop_assert!(
            fast.cache_hit_tokens <= fast.prompt_tokens,
            "more hits than prompt tokens"
        );
        let mut prev = 0usize;
        for p in &fast.timeline.points {
            prop_assert!(
                p.cache_hit_tokens >= prev,
                "cumulative hit counter decreased"
            );
            prev = p.cache_hit_tokens;
        }
        prop_assert!(
            fast.timeline.points.last().map(|p| p.cache_hit_tokens)
                == Some(fast.cache_hit_tokens),
            "final timeline hit count != serve total"
        );
        Ok(())
    });
}

#[test]
fn prop_kvcache_live_decoded_matches_mirror() {
    // The incrementally maintained live_decoded_tokens counter must equal
    // a from-scratch mirror under random admit/decode/release
    // interleavings, and stale (released) handles must stay rejected even
    // after their slab slots are reused.
    check("kv_live_decoded", default_cases(), |rng| {
        let mut kv = KvCacheManager::new(4096 * 16, 16);
        let mut live: Vec<(sart::kvcache::BranchId, usize)> = Vec::new();
        let mut dead: Vec<sart::kvcache::BranchId> = Vec::new();
        let mut total = 0usize;
        for _ in 0..300 {
            match rng.below(3) {
                0 if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let (b, grown) = live.swap_remove(i);
                    kv.release_branch(b).map_err(|e| e.to_string())?;
                    total -= grown;
                    dead.push(b);
                }
                1 if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let toks = 1 + rng.below(16);
                    kv.note_decode(live[i].0, toks)
                        .map_err(|e| e.to_string())?;
                    live[i].1 += toks;
                    total += toks;
                }
                _ => {
                    let n = 1 + rng.below(4);
                    let p: Vec<tok::Token> = (0..27).collect();
                    if let AdmissionOutcome::Admitted(adm) = kv
                        .admit(&AdmissionRequest::monolithic(&p, 64, n))
                        .map_err(|e| e.to_string())?
                    {
                        live.extend(adm.branches.into_iter().map(|b| (b, 0)));
                    }
                }
            }
            prop_assert!(
                kv.live_decoded_tokens() == total,
                "live_decoded {} != mirror {total}",
                kv.live_decoded_tokens()
            );
            kv.check_invariants().map_err(|e| e.to_string())?;
            if let Some(&b) = dead.last() {
                prop_assert!(
                    kv.note_decode(b, 1).is_err(),
                    "note_decode on released branch succeeded"
                );
                prop_assert!(
                    kv.release_branch(b).is_err(),
                    "double release succeeded"
                );
            }
        }
        for (b, _) in live.drain(..) {
            kv.release_branch(b).map_err(|e| e.to_string())?;
        }
        prop_assert!(kv.live_decoded_tokens() == 0, "leaked decoded tokens");
        prop_assert!(kv.used_pages() == 0, "leaked pages");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Cluster dispatch layer vs the single-engine scheduler.
// ---------------------------------------------------------------------------

struct ClusterCase {
    policy: Policy,
    slots: usize,
    t_round: usize,
    kv_tokens: usize,
    seed: u64,
    spec: TaskSpec,
    trace: Vec<sart::workload::Request>,
}

fn cluster_case(rng: &mut Rng) -> ClusterCase {
    let policy = random_policy(rng);
    let slots = 2 + rng.below(14);
    let n_req = 4 + rng.below(12);
    let rate = 0.5 + 4.0 * rng.f64();
    let spec = if rng.chance(0.5) {
        TaskSpec::synth_gaokao()
    } else {
        TaskSpec::synth_gpqa()
    };
    let seed = rng.next_u64();
    // Budget always admits at least one full request (no stalls).
    let min_pages = 2 + policy.n_branches() * 14 + 4;
    let kv_tokens = 16 * (min_pages + rng.below(1024));
    let trace = poisson_trace(&spec, n_req, rate, seed);
    ClusterCase {
        policy,
        slots,
        t_round: 8 + rng.below(24),
        kv_tokens,
        seed,
        spec,
        trace,
    }
}

fn case_sched_cfg(c: &ClusterCase) -> SchedConfig {
    SchedConfig {
        policy: c.policy,
        t_round: c.t_round,
        temperature: 1.0,
        max_new: 224,
        kv: KvConfig::new(c.kv_tokens, 16),
        adaptive: None,
        seed: c.seed,
    }
}

/// Engines/PRMs for `n` replicas, using the same per-replica seed
/// *stride* scheme as `server::run_cluster_on_trace` (replica 0 keeps
/// the base seed). The exact PRM seed/sigma differ from the server's
/// `build_prm` — the identity property only needs the single-engine and
/// cluster runs here to share one self-consistent seeding, which they
/// do (the single run below uses the replica-0 values).
fn case_stacks(
    c: &ClusterCase,
    n: usize,
) -> (Vec<Box<dyn Engine>>, Vec<Box<dyn PrmScorer>>) {
    let engines: Vec<Box<dyn Engine>> = (0..n)
        .map(|_| {
            Box::new(SimEngine::new(
                c.slots,
                256,
                c.spec.clone(),
                SimCostModel::default(),
            )) as Box<dyn Engine>
        })
        .collect();
    let prms: Vec<Box<dyn PrmScorer>> = (0..n)
        .map(|i| {
            let seed = c.seed ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
            Box::new(OraclePrm::new(0.1, seed ^ 7)) as Box<dyn PrmScorer>
        })
        .collect();
    (engines, prms)
}

#[test]
fn prop_cluster_single_replica_is_byte_identical() {
    // A 1-replica cluster serve must reproduce `Scheduler::serve` on the
    // same trace exactly — same outcomes, same timeline, same round count
    // — under every dispatch policy. Audit mode is on in the cluster run,
    // so this doubles as an audit-mode pass over the threshold/quorum
    // bookkeeping on random workloads.
    check("cluster_r1_identity", 8, |rng| {
        let c = cluster_case(rng);
        let mut engine = SimEngine::new(
            c.slots,
            256,
            c.spec.clone(),
            SimCostModel::default(),
        );
        let mut prm = OraclePrm::new(0.1, c.seed ^ 7);
        let mut sched = Scheduler::new(
            case_sched_cfg(&c),
            &mut engine,
            &mut prm,
            ClockHandle::Sim(SimClock::new()),
        );
        let single = sched.serve(&c.trace).map_err(|e| e.to_string())?;
        for lb in LbPolicy::ALL {
            let (mut engines, mut prms) = case_stacks(&c, 1);
            let ccfg = ClusterConfig {
                replicas: 1,
                lb,
                sched: case_sched_cfg(&c),
                seed: c.seed,
                audit: true,
                gossip_rounds: 0,
                gossip_adapt: false,
                fault_plan: Default::default(),
                scale: None,
            };
            let res = serve_cluster(&ccfg, &mut engines, &mut prms, &c.trace)
                .map_err(|e| format!("{lb:?}: {e}"))?;
            prop_assert!(
                res.outcomes == single.outcomes,
                "outcomes diverge under {lb:?}"
            );
            prop_assert!(
                res.replica_results[0].timeline.points
                    == single.timeline.points,
                "timeline diverges under {lb:?}"
            );
            prop_assert!(
                res.replica_results[0].rounds == single.rounds,
                "round count diverges under {lb:?}"
            );
            prop_assert!(
                res.assignments.iter().all(|&a| a == 0),
                "single replica got assignment != 0"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_cluster_serves_all_under_every_policy() {
    // Multi-replica serves (audit on in every replica) must serve every
    // request exactly once with sane per-request invariants, and
    // round-robin must assign cyclically.
    check("cluster_serves_all", 6, |rng| {
        let c = cluster_case(rng);
        let replicas = 2 + rng.below(3); // 2..=4
        for lb in LbPolicy::ALL {
            let (mut engines, mut prms) = case_stacks(&c, replicas);
            let ccfg = ClusterConfig {
                replicas,
                lb,
                sched: case_sched_cfg(&c),
                seed: c.seed,
                audit: true,
                gossip_rounds: 0,
                gossip_adapt: false,
                fault_plan: Default::default(),
                scale: None,
            };
            let res = serve_cluster(&ccfg, &mut engines, &mut prms, &c.trace)
                .map_err(|e| format!("{lb:?}: {e}"))?;
            prop_assert!(
                res.outcomes.len() == c.trace.len(),
                "lost requests under {lb:?}"
            );
            prop_assert!(
                res.assignments.len() == c.trace.len()
                    && res.assignments.iter().all(|&a| a < replicas),
                "bad assignment vector under {lb:?}"
            );
            for (o, r) in res.outcomes.iter().zip(&c.trace) {
                prop_assert!(o.id == r.id, "merge order broken: {lb:?}");
                prop_assert!(
                    o.finished_at >= o.arrival && o.admitted_at >= o.arrival,
                    "time travel under {lb:?}"
                );
            }
            if lb == LbPolicy::RoundRobin {
                for (i, &a) in res.assignments.iter().enumerate() {
                    prop_assert!(
                        a == i % replicas,
                        "round-robin not cyclic at {i}"
                    );
                }
            }
            let report = res.report();
            prop_assert!(
                report.per_replica_requests.iter().sum::<usize>()
                    == c.trace.len(),
                "per-replica counts don't sum under {lb:?}"
            );
            prop_assert!(
                report.request_skew >= 1.0 - 1e-12,
                "skew below 1 under {lb:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_cluster_event_pump_is_byte_identical() {
    // `serve_cluster_with` must schedule byte-identically to
    // `serve_cluster` (audit on in every replica): same merged outcomes,
    // same per-replica timelines and assignments. Its replica-tagged
    // event stream must finalize every trace entry exactly once, on the
    // replica the dispatcher assigned it to.
    check("cluster_event_pump", 6, |rng| {
        let c = cluster_case(rng);
        let replicas = 2;
        let lb = LbPolicy::ALL[rng.below(LbPolicy::ALL.len())];
        let ccfg = ClusterConfig {
            replicas,
            lb,
            sched: case_sched_cfg(&c),
            seed: c.seed,
            audit: true,
            gossip_rounds: 0,
            gossip_adapt: false,
            fault_plan: Default::default(),
            scale: None,
        };
        let (mut engines, mut prms) = case_stacks(&c, replicas);
        let plain = serve_cluster(&ccfg, &mut engines, &mut prms, &c.trace)
            .map_err(|e| format!("{lb:?}: {e}"))?;
        let (mut engines, mut prms) = case_stacks(&c, replicas);
        let mut events: Vec<(usize, ServeEvent)> = Vec::new();
        let pumped = serve_cluster_with(
            &ccfg,
            &mut engines,
            &mut prms,
            &c.trace,
            &mut |replica, ev| events.push((replica, ev)),
        )
        .map_err(|e| format!("{lb:?} (pumped): {e}"))?;
        prop_assert!(
            plain.outcomes == pumped.outcomes,
            "outcomes diverge under {lb:?}"
        );
        prop_assert!(
            plain.assignments == pumped.assignments,
            "assignments diverge under {lb:?}"
        );
        for (i, (a, b)) in plain
            .replica_results
            .iter()
            .zip(&pumped.replica_results)
            .enumerate()
        {
            prop_assert!(
                a.timeline.points == b.timeline.points,
                "replica {i} timeline diverges under {lb:?}"
            );
        }
        prop_assert!(
            events.iter().all(|(r, _)| *r < replicas),
            "replica tag out of range"
        );
        for (i, req) in c.trace.iter().enumerate() {
            let finals: Vec<usize> = events
                .iter()
                .filter_map(|(replica, ev)| match ev {
                    ServeEvent::Finalized { request, .. }
                        if *request == req.id =>
                    {
                        Some(*replica)
                    }
                    _ => None,
                })
                .collect();
            prop_assert!(
                finals.len() == 1,
                "request {} finalized {} times under {lb:?}",
                req.id,
                finals.len()
            );
            prop_assert!(
                finals[0] == pumped.assignments[i],
                "request {} finalized on replica {} but assigned {}",
                req.id,
                finals[0],
                pumped.assignments[i]
            );
        }
        Ok(())
    });
}

#[test]
fn affinity_routing_beats_p2c_on_cache_hits() {
    // Deterministic cluster comparison on a prefix-heavy trace: with
    // per-replica cache budgets too small to hold every template,
    // prefix-affinity pins each few-shot template to the replica already
    // holding its pages, while p2c scatters templates across all
    // replicas and churns every cache. Affinity must achieve a strictly
    // higher cluster-wide hit rate (and a 1-replica cluster must agree
    // between the two policies, since affinity only changes *placement*).
    let spec = TaskSpec::synth_gaokao();
    let trace =
        sart::workload::templated_trace(&spec, 96, 6.0, 42, 0.85, 3, 3);
    let run = |lb: LbPolicy, replicas: usize| {
        let mut engines: Vec<Box<dyn Engine>> = (0..replicas)
            .map(|_| {
                let mut e = SimEngine::new(8, 512, spec.clone(),
                                           SimCostModel::default());
                e.set_prompt_bucket(256);
                Box::new(e) as Box<dyn Engine>
            })
            .collect();
        let mut prms: Vec<Box<dyn PrmScorer>> = (0..replicas)
            .map(|i| {
                let seed = 42 ^ (i as u64).wrapping_mul(REPLICA_SEED_STRIDE);
                Box::new(OraclePrm::new(0.1, seed ^ 7)) as Box<dyn PrmScorer>
            })
            .collect();
        let ccfg = ClusterConfig {
            replicas,
            lb,
            sched: SchedConfig {
                policy: Policy::Sart { n: 4, m: 2, alpha: 0.5, beta: 2 },
                t_round: 16,
                temperature: 1.0,
                max_new: 224,
                kv: KvConfig::new(32768, 16)
                    .with_prefix_cache(24),
                adaptive: None,
                seed: 42,
            },
            seed: 42,
            audit: true,
            gossip_rounds: 0,
            gossip_adapt: false,
            fault_plan: Default::default(),
            scale: None,
        };
        let res = serve_cluster(&ccfg, &mut engines, &mut prms, &trace)
            .expect("cluster serve");
        assert_eq!(res.outcomes.len(), trace.len());
        res.cache_hit_rate()
    };
    let aff = run(LbPolicy::PrefixAffinity, 3);
    let p2c = run(LbPolicy::PowerOfTwoChoices, 3);
    assert!(aff > 0.0, "affinity produced no cache hits");
    assert!(
        aff > p2c,
        "prefix-affinity hit rate {aff:.3} must strictly beat p2c {p2c:.3}"
    );
    // R = 1: placement is forced either way, so hit rates coincide.
    let aff1 = run(LbPolicy::PrefixAffinity, 1);
    let p2c1 = run(LbPolicy::PowerOfTwoChoices, 1);
    assert_eq!(aff1, p2c1, "R=1 must be placement-independent");
}

// ---------------------------------------------------------------------------
// Order statistics (Lemma 1) against Monte-Carlo.
// ---------------------------------------------------------------------------

#[test]
fn prop_lemma1_cdf_monotone_in_n() {
    check("lemma1_monotone", default_cases(), |rng| {
        let f = rng.f64();
        let m = 1 + rng.below(6) as u64;
        let mut prev = -1.0;
        for n in m..m + 10 {
            let c = sart::analysis::order_statistic_cdf(f, m, n);
            prop_assert!(
                c >= prev - 1e-12,
                "CDF not monotone at f={f} m={m} n={n}"
            );
            prop_assert!((0.0..=1.0 + 1e-12).contains(&c), "CDF out of range");
            prev = c;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Tokenizer / workload structural invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_prompt_roundtrip() {
    check("prompt_roundtrip", default_cases(), |rng| {
        let spec = if rng.chance(0.5) {
            TaskSpec::synth_gaokao()
        } else {
            TaskSpec::synth_gpqa()
        };
        let q = Question::sample(&spec, rng);
        let parsed = Question::from_prompt(&q.prompt_tokens())
            .map_err(|e| e.to_string())?;
        prop_assert!(parsed == q, "prompt roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_scripted_answer_extraction_consistent() {
    check("answer_extraction", default_cases(), |rng| {
        let spec = TaskSpec::synth_gpqa();
        let q = Question::sample(&spec, rng);
        let resp =
            sart::workload::sample_response(&q, &spec, rng, 256);
        let ans = tok::extract_answer(&resp);
        prop_assert!(ans.is_some(), "no answer in well-formed response");
        // The answer digit is the token right before EOS.
        let eos_pos = resp.len() - 1;
        prop_assert!(resp[eos_pos] == tok::EOS, "missing EOS");
        prop_assert!(
            tok::digit_value(resp[eos_pos - 1]) == ans,
            "answer position mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_chain_state_tracks_forced_prefixes() {
    check("chain_state", default_cases(), |rng| {
        let mut spec = TaskSpec::synth_gaokao();
        spec.p_err = 0.0; // clean chains parse exactly
        spec.p_rethink = 0.0;
        let q = Question::sample(&spec, rng);
        let resp = sart::workload::sample_response(&q, &spec, rng, 256);
        // Steps region: everything before </think> (4 tokens per step).
        let steps_end = resp
            .iter()
            .position(|&t| t == tok::ETHINK)
            .ok_or("no </think>")?;
        let n_steps = steps_end / 4;
        for k in 0..=n_steps {
            let st = sart::workload::chain_state(&q, &resp[..4 * k]);
            prop_assert!(st.is_some(), "boundary {k} failed to parse");
            let (_, steps) = st.unwrap();
            prop_assert!(steps == k as u32, "step count mismatch at {k}");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Stats utilities.
// ---------------------------------------------------------------------------

#[test]
fn prop_percentile_bounds_and_order() {
    check("percentile", default_cases(), |rng| {
        let n = 1 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 100.0).collect();
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        let mut prev = lo;
        for p in [0.0, 10.0, 50.0, 90.0, 97.0, 99.0, 100.0] {
            let v = percentile(&xs, p);
            prop_assert!(v >= lo - 1e-9 && v <= hi + 1e-9, "out of range");
            prop_assert!(v >= prev - 1e-9, "not monotone in p");
            prev = v;
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary() {
    use sart::util::json::Json;
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = b"ab\"\\\nxyz 09"[rng.below(11)];
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json_roundtrip", default_cases(), |rng| {
        let j = gen(rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(back == j, "roundtrip mismatch for {text}");
        Ok(())
    });
}
