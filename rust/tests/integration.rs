//! Integration tests over the real runtime + artifacts.
//!
//! These exercise the PJRT path end to end (manifest → compile →
//! device-resident state → decode → readback). They are skipped (with a
//! visible marker) when `artifacts/` has not been built, so `cargo test`
//! stays green on a fresh checkout; the dev flow is `make artifacts`
//! first.

use sart::config::{Args, EngineChoice, Method, PrmChoice, ServeSpec};
use sart::engine::hlo::{DecodeMode, HloEngine};
use sart::engine::{Engine, PrefillEntry};
use sart::prm::{HloPrm, PrmScorer};
use sart::runtime::{Manifest, Runtime, StateLayout};
use sart::tokenizer as tok;
use sart::util::rng::Rng;
use sart::workload::{Question, TaskSpec};

fn manifest() -> Option<Manifest> {
    match Manifest::load(sart::runtime::artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn question(seed: u64) -> Question {
    let mut rng = Rng::new(seed);
    Question::sample(&TaskSpec::synth_gaokao(), &mut rng)
}

#[test]
fn manifest_layout_crosscheck() {
    let Some(man) = manifest() else { return };
    // The rust-recomputed packed-state layout must match the HLO
    // signatures that python exported.
    for (name, art) in &man.models {
        for &b in &art.decode.batches() {
            let layout = StateLayout::new(&art.config, b, art.chunk_t);
            let text =
                std::fs::read_to_string(&art.decode.by_batch[&b]).unwrap();
            assert!(
                text.contains(&format!("f32[{}]", layout.total)),
                "{name} b{b}: state size {} not found in HLO",
                layout.total
            );
        }
    }
    // Dataset presets in the manifest match the rust mirrors.
    for (name, spec) in &man.datasets {
        assert_eq!(spec, &TaskSpec::by_name(name).unwrap());
    }
}

#[test]
fn hlo_engine_generates_wellformed_responses() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut eng =
        HloEngine::load(rt, &man, "r1mini-tiny", 4, DecodeMode::Fused, 7)
            .unwrap();
    let q = question(3);
    let entries: Vec<PrefillEntry> = (0..4)
        .map(|s| PrefillEntry {
            slot: s,
            prompt: q.prompt_tokens(),
            seed: s as u64 + 100,
            cached_tokens: 0,
        })
        .collect();
    eng.prefill(&entries).unwrap();
    let mut gens: Vec<Vec<tok::Token>> = vec![Vec::new(); 4];
    for _ in 0..20 {
        let active: Vec<usize> = (0..4)
            .filter(|&s| gens[s].last() != Some(&tok::EOS)
                && gens[s].len() < 224)
            .collect();
        if active.is_empty() {
            break;
        }
        let r = eng.decode(&active, 16, 1.0).unwrap();
        for (slot, toks) in &r.emitted {
            gens[*slot].extend_from_slice(toks);
        }
    }
    let mut answered = 0;
    for g in &gens {
        assert!(!g.is_empty(), "no tokens generated");
        assert!(g.iter().all(|&t| (0..32).contains(&t)), "out-of-vocab");
        if g.last() == Some(&tok::EOS) && tok::extract_answer(g).is_some() {
            answered += 1;
        }
    }
    assert!(answered >= 2, "only {answered}/4 branches answered");
}

#[test]
fn fused_and_stepwise_both_complete() {
    let Some(man) = manifest() else { return };
    for mode in [DecodeMode::Fused, DecodeMode::Stepwise] {
        let rt = Runtime::cpu().unwrap();
        let mut eng =
            HloEngine::load(rt, &man, "r1mini-tiny", 2, mode, 11).unwrap();
        let q = question(5);
        eng.prefill(&[PrefillEntry {
            slot: 0,
            prompt: q.prompt_tokens(),
            seed: 1,
            cached_tokens: 0,
        }])
        .unwrap();
        let mut gen: Vec<tok::Token> = Vec::new();
        for _ in 0..20 {
            if gen.last() == Some(&tok::EOS) || gen.len() >= 224 {
                break;
            }
            let r = eng.decode(&[0], 16, 1.0).unwrap();
            gen.extend(r.emitted[0].1.iter());
        }
        assert!(
            gen.last() == Some(&tok::EOS) || gen.len() >= 224,
            "{mode:?}: did not terminate ({} tokens)",
            gen.len()
        );
    }
}

#[test]
fn slot_reuse_after_release() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut eng =
        HloEngine::load(rt, &man, "r1mini-tiny", 2, DecodeMode::Fused, 13)
            .unwrap();
    let q1 = question(8);
    eng.prefill(&[PrefillEntry { slot: 0, prompt: q1.prompt_tokens(), seed: 1, cached_tokens: 0 }])
        .unwrap();
    eng.decode(&[0], 16, 1.0).unwrap();
    eng.release(0);
    let q2 = question(9);
    eng.prefill(&[PrefillEntry { slot: 0, prompt: q2.prompt_tokens(), seed: 2, cached_tokens: 0 }])
        .unwrap();
    let r = eng.decode(&[0], 16, 1.0).unwrap();
    assert!(!r.emitted[0].1.is_empty());
}

#[test]
fn replay_teacher_forces_prefix() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut eng =
        HloEngine::load(rt, &man, "r1mini-tiny", 2, DecodeMode::Stepwise, 17)
            .unwrap();
    let q = question(12);
    let forced = vec![tok::STEP, tok::digit(q.start), tok::EQUALS,
                      tok::digit(q.mapping[q.start as usize])];
    eng.replay(&[sart::engine::ReplayEntry {
        slot: 0,
        prompt: q.prompt_tokens(),
        forced: forced.clone(),
        seed: 3,
    }])
    .unwrap();
    let r = eng.decode(&[0], 8, 1.0).unwrap();
    assert!(!r.emitted[0].1.is_empty(), "fork did not continue generating");
}

#[test]
fn hlo_prm_scores_and_discriminates_weakly() {
    let Some(man) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut prm = HloPrm::load(rt, &man, 8).unwrap();
    // The PRM was trained on trajectory-level labels (prefix of a
    // trajectory whose final answer is correct → 1). Mirror that eval:
    // score full corpus-style trajectories and compare the mean reward of
    // correct vs incorrect ones (held-out AUC ≈ 0.64 → the group means
    // must order correctly over a decent sample).
    let spec = TaskSpec::synth_gpqa(); // higher p_err → both groups present
    let mut correct_scores = Vec::new();
    let mut wrong_scores = Vec::new();
    let mut seqs: Vec<Vec<tok::Token>> = Vec::new();
    let mut is_correct: Vec<bool> = Vec::new();
    for seed in 0..96u64 {
        let mut rng = Rng::new(seed);
        let q = Question::sample(&spec, &mut rng);
        let resp = sart::workload::sample_response(&q, &spec, &mut rng, 256);
        let ok = tok::extract_answer(&resp) == Some(q.answer());
        let mut full = q.prompt_tokens();
        full.extend(resp);
        seqs.push(full);
        is_correct.push(ok);
    }
    let refs: Vec<&[tok::Token]> = seqs.iter().map(|s| s.as_slice()).collect();
    let scores = prm.score(&refs).unwrap();
    for (s, ok) in scores.iter().zip(&is_correct) {
        assert!((0.0..=1.0).contains(s), "reward out of range: {s}");
        if *ok {
            correct_scores.push(*s);
        } else {
            wrong_scores.push(*s);
        }
    }
    assert!(correct_scores.len() >= 10 && wrong_scores.len() >= 10,
            "degenerate sample: {} vs {}", correct_scores.len(),
            wrong_scores.len());
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    assert!(
        mean(&correct_scores) > mean(&wrong_scores),
        "PRM failed to rank correct ({}) above wrong ({})",
        mean(&correct_scores),
        mean(&wrong_scores)
    );
}

#[test]
fn prm_seq_buckets_agree() {
    let Some(man) = manifest() else { return };
    // The same short sequence must score (nearly) identically through
    // different sequence buckets — bucketing is a pure perf optimization.
    let rt = Runtime::cpu().unwrap();
    let mut prm = HloPrm::load(rt, &man, 8).unwrap();
    let q = question(21);
    let short = q.prompt_tokens(); // 27 tokens → smallest bucket
    let s1 = prm.score(&[&short]).unwrap()[0];
    // Force the big bucket by batching with a long sequence.
    let mut rng = Rng::new(22);
    let spec = TaskSpec::synth_gpqa();
    let q2 = Question::sample(&spec, &mut rng);
    let mut long = q2.prompt_tokens();
    long.extend(sart::workload::sample_response(&q2, &spec, &mut rng, 256));
    while long.len() < 150 {
        long.push(tok::RECHECK);
    }
    let s2 = prm.score(&[&long, &short]).unwrap()[1];
    assert!((s1 - s2).abs() < 1e-4, "bucket mismatch: {s1} vs {s2}");
}

#[test]
fn serve_spec_end_to_end_tiny() {
    let Some(_man) = manifest() else { return };
    // Small full-coordinator run on the real engine via the public API.
    let args = Args::parse(
        "--engine hlo --model r1mini-tiny --method sart:2 --requests 3 \
         --rate 0 --slots 4 --kv-tokens 4096"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    let spec = ServeSpec::from_args(&args).unwrap();
    assert_eq!(spec.method, Method::Sart { n: 2, m: 1, alpha: 0.5, beta: 1 });
    assert_eq!(spec.prm, PrmChoice::Hlo);
    assert!(matches!(spec.engine, EngineChoice::Hlo { .. }));
    let out = sart::server::run(&spec).unwrap();
    assert_eq!(out.report.n_requests, 3);
    assert!(out.report.answered > 0.5);
    for o in &out.outcomes {
        assert!(o.finished_at > o.arrival);
    }
}
