"""Tests for tools/check_bench.py — the single source of truth for CI's
bench gates deserves its own gate.

Covers: schema rejection, the per-bench headline gates (including the
BENCH_gossip gate), ``--require`` failure, and ``--delta`` output. Run
with ``python3 -m pytest tools/test_check_bench.py`` (CI does, before the
rust jobs) or ``python3 -m unittest tools.test_check_bench``.
"""

from __future__ import annotations

import io
import json
import os
import sys
import unittest
from contextlib import redirect_stderr, redirect_stdout
from tempfile import TemporaryDirectory

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import check_bench  # noqa: E402


def result_row(name="row", iters=3, mean_us=10.0, p50_us=9.0, p95_us=12.0):
    return {
        "name": name,
        "iters": iters,
        "mean_us": mean_us,
        "p50_us": p50_us,
        "p95_us": p95_us,
    }


def report(bench="scheduler", results=None, metrics=None):
    return {
        "bench": bench,
        "results": [result_row()] if results is None else results,
        "metrics": {} if metrics is None else metrics,
    }


class CheckBenchCase(unittest.TestCase):
    def setUp(self):
        self._tmp = TemporaryDirectory()
        self.dir = self._tmp.name

    def tearDown(self):
        self._tmp.cleanup()

    def write(self, basename, doc):
        path = os.path.join(self.dir, basename)
        with open(path, "w") as f:
            if isinstance(doc, dict):
                json.dump(doc, f)
            else:
                f.write(doc)
        return path

    def run_main(self, argv):
        """Run check_bench.main, capturing stdout/stderr and exit code."""
        out, err = io.StringIO(), io.StringIO()
        with redirect_stdout(out), redirect_stderr(err):
            code = check_bench.main(argv)
        return code, out.getvalue(), err.getvalue()


class TestSchemaValidation(CheckBenchCase):
    def test_valid_report_passes(self):
        path = self.write("BENCH_scheduler.json", report())
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("all bench gates passed", out)

    def test_invalid_json_rejected(self):
        path = self.write("BENCH_broken.json", "{not json")
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("unreadable or invalid JSON", err)

    def test_missing_top_level_key_rejected(self):
        doc = report()
        del doc["metrics"]
        path = self.write("BENCH_scheduler.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("missing top-level key `metrics`", err)

    def test_non_finite_metric_rejected(self):
        # json.dumps would emit bare NaN; write it verbatim the way a
        # buggy serializer might.
        path = self.write(
            "BENCH_scheduler.json",
            '{"bench": "scheduler", "results": [], '
            '"metrics": {"x": NaN}}',
        )
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("finite number", err)

    def test_malformed_result_row_rejected(self):
        doc = report(results=[{"name": "row", "iters": 3}])
        path = self.write("BENCH_scheduler.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("results[0] missing `mean_us`", err)


class TestGates(CheckBenchCase):
    def test_cluster_gate_fails_at_ratio_one(self):
        doc = report(bench="cluster", metrics={"p2c_vs_rr_p99_ratio": 1.0})
        path = self.write("BENCH_cluster.json", doc)
        code, out, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gate `cluster`: FAIL", out)
        self.assertIn("p2c_vs_rr_p99_ratio", err)

    def test_gossip_gate_passes_on_good_report(self):
        doc = report(
            bench="gossip",
            metrics={
                "gossip_vs_probe_hit_rate_ratio": 0.99,
                "probe_calls_per_request_gossip": 0.0,
            },
        )
        path = self.write("BENCH_gossip.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `gossip`: PASS", out)

    def test_gossip_gate_fails_below_ratio_floor(self):
        doc = report(
            bench="gossip",
            metrics={
                "gossip_vs_probe_hit_rate_ratio": 0.90,
                "probe_calls_per_request_gossip": 0.0,
            },
        )
        path = self.write("BENCH_gossip.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gossip_vs_probe_hit_rate_ratio", err)

    def test_gossip_gate_fails_on_any_probe_call(self):
        doc = report(
            bench="gossip",
            metrics={
                "gossip_vs_probe_hit_rate_ratio": 1.0,
                "probe_calls_per_request_gossip": 4.0,
            },
        )
        path = self.write("BENCH_gossip.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("probe_calls_per_request_gossip", err)

    def test_gossip_gate_fails_on_missing_metric(self):
        doc = report(bench="gossip", metrics={})
        path = self.write("BENCH_gossip.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gossip_vs_probe_hit_rate_ratio", err)


def faults_metrics(**overrides):
    metrics = {
        "faults_requests_lost": 0.0,
        "faults_vs_static_p99_ratio": 1.6,
        "rewarm_hit_rate_recovery": 1.1,
    }
    metrics.update(overrides)
    return metrics


class TestFaultsGate(CheckBenchCase):
    def test_faults_gate_passes_on_good_report(self):
        doc = report(bench="faults", metrics=faults_metrics())
        path = self.write("BENCH_faults.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `faults`: PASS", out)

    def test_faults_gate_fails_on_any_lost_request(self):
        doc = report(
            bench="faults", metrics=faults_metrics(faults_requests_lost=1.0)
        )
        path = self.write("BENCH_faults.json", doc)
        code, out, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gate `faults`: FAIL", out)
        self.assertIn("faults_requests_lost", err)

    def test_faults_gate_fails_at_p99_ratio_ceiling(self):
        doc = report(
            bench="faults",
            metrics=faults_metrics(faults_vs_static_p99_ratio=5.0),
        )
        path = self.write("BENCH_faults.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("faults_vs_static_p99_ratio", err)

    def test_faults_gate_fails_below_recovery_floor(self):
        doc = report(
            bench="faults",
            metrics=faults_metrics(rewarm_hit_rate_recovery=0.4),
        )
        path = self.write("BENCH_faults.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("rewarm_hit_rate_recovery", err)

    def test_faults_gate_fails_on_missing_metric(self):
        doc = report(bench="faults", metrics={})
        path = self.write("BENCH_faults.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("faults_requests_lost", err)


def serving_metrics(**overrides):
    metrics = {
        "serving_requests_lost": 0.0,
        "wall_vs_virtual_p99_ratio": 3.0,
    }
    metrics.update(overrides)
    return metrics


class TestServingGate(CheckBenchCase):
    def test_serving_gate_passes_on_good_report(self):
        doc = report(bench="serving", metrics=serving_metrics())
        path = self.write("BENCH_serving.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `serving`: PASS", out)

    def test_serving_gate_fails_on_any_lost_request(self):
        doc = report(
            bench="serving",
            metrics=serving_metrics(serving_requests_lost=1.0),
        )
        path = self.write("BENCH_serving.json", doc)
        code, out, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gate `serving`: FAIL", out)
        self.assertIn("serving_requests_lost", err)

    def test_serving_gate_fails_at_ratio_ceiling(self):
        doc = report(
            bench="serving",
            metrics=serving_metrics(wall_vs_virtual_p99_ratio=50.0),
        )
        path = self.write("BENCH_serving.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("wall_vs_virtual_p99_ratio", err)

    def test_serving_gate_fails_on_missing_metric(self):
        doc = report(bench="serving", metrics={})
        path = self.write("BENCH_serving.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("serving_requests_lost", err)


def live_faults_metrics(**overrides):
    metrics = {
        "live_faults_requests_lost": 0.0,
        "live_faults_migrated_sessions": 4.0,
        "live_faulted_vs_clean_p99_ratio": 1.8,
    }
    metrics.update(overrides)
    return metrics


class TestLiveFaultsGate(CheckBenchCase):
    def test_live_faults_gate_passes_on_good_report(self):
        doc = report(bench="live_faults", metrics=live_faults_metrics())
        path = self.write("BENCH_live_faults.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `live_faults`: PASS", out)

    def test_live_faults_gate_fails_on_any_lost_session(self):
        doc = report(
            bench="live_faults",
            metrics=live_faults_metrics(live_faults_requests_lost=1.0),
        )
        path = self.write("BENCH_live_faults.json", doc)
        code, out, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gate `live_faults`: FAIL", out)
        self.assertIn("live_faults_requests_lost", err)

    def test_live_faults_gate_fails_when_no_session_migrated(self):
        doc = report(
            bench="live_faults",
            metrics=live_faults_metrics(live_faults_migrated_sessions=0.0),
        )
        path = self.write("BENCH_live_faults.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("live_faults_migrated_sessions", err)

    def test_live_faults_gate_fails_at_ratio_ceiling(self):
        doc = report(
            bench="live_faults",
            metrics=live_faults_metrics(
                live_faulted_vs_clean_p99_ratio=10.0
            ),
        )
        path = self.write("BENCH_live_faults.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("live_faulted_vs_clean_p99_ratio", err)

    def test_live_faults_gate_fails_on_missing_metric(self):
        doc = report(bench="live_faults", metrics={})
        path = self.write("BENCH_live_faults.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("live_faults_requests_lost", err)


def pressure_metrics(**overrides):
    metrics = {
        "pressure_requests_lost": 0.0,
        "pressure_admitted_at_budget_ratio": 1.4,
    }
    metrics.update(overrides)
    return metrics


class TestPressureGate(CheckBenchCase):
    def test_pressure_gate_passes_on_good_report(self):
        doc = report(bench="pressure", metrics=pressure_metrics())
        path = self.write("BENCH_pressure.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `pressure`: PASS", out)

    def test_pressure_gate_fails_on_any_lost_request(self):
        doc = report(
            bench="pressure",
            metrics=pressure_metrics(pressure_requests_lost=1.0),
        )
        path = self.write("BENCH_pressure.json", doc)
        code, out, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("gate `pressure`: FAIL", out)
        self.assertIn("pressure_requests_lost", err)

    def test_pressure_gate_fails_at_ratio_one(self):
        # Exactly 1.0 means streaming + preemption admitted no more than
        # all-or-nothing: the headline must be *strictly* better.
        doc = report(
            bench="pressure",
            metrics=pressure_metrics(pressure_admitted_at_budget_ratio=1.0),
        )
        path = self.write("BENCH_pressure.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("pressure_admitted_at_budget_ratio", err)

    def test_pressure_gate_fails_on_missing_metric(self):
        doc = report(bench="pressure", metrics={})
        path = self.write("BENCH_pressure.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("pressure_requests_lost", err)


def adaptive_metrics(**overrides):
    metrics = {
        "adaptive_requests_lost": 0.0,
        "baseline_requests_lost": 0.0,
        "adaptive_vs_static_tokens_ratio": 0.7,
        "adaptive_vs_static_accuracy_delta": -0.01,
        "adaptive_fast_path_share": 0.4,
    }
    metrics.update(overrides)
    return metrics


class TestAdaptiveGate(CheckBenchCase):
    def test_adaptive_gate_passes_on_good_report(self):
        doc = report(bench="adaptive", metrics=adaptive_metrics())
        path = self.write("BENCH_adaptive.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `adaptive`: PASS", out)

    def test_adaptive_gate_fails_on_lost_request_either_side(self):
        for key in ("adaptive_requests_lost", "baseline_requests_lost"):
            doc = report(
                bench="adaptive", metrics=adaptive_metrics(**{key: 1.0})
            )
            path = self.write("BENCH_adaptive.json", doc)
            code, out, err = self.run_main([path])
            self.assertEqual(code, 1)
            self.assertIn("gate `adaptive`: FAIL", out)
            self.assertIn(key, err)

    def test_adaptive_gate_fails_at_tokens_ratio_one(self):
        # Exactly 1.0 means adapting saved nothing: the headline must be
        # *strictly* under the static baseline.
        doc = report(
            bench="adaptive",
            metrics=adaptive_metrics(adaptive_vs_static_tokens_ratio=1.0),
        )
        path = self.write("BENCH_adaptive.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("adaptive_vs_static_tokens_ratio", err)

    def test_adaptive_gate_fails_below_accuracy_floor(self):
        doc = report(
            bench="adaptive",
            metrics=adaptive_metrics(
                adaptive_vs_static_accuracy_delta=-0.06
            ),
        )
        path = self.write("BENCH_adaptive.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("adaptive_vs_static_accuracy_delta", err)

    def test_adaptive_gate_allows_accuracy_delta_at_floor(self):
        doc = report(
            bench="adaptive",
            metrics=adaptive_metrics(
                adaptive_vs_static_accuracy_delta=-0.05
            ),
        )
        path = self.write("BENCH_adaptive.json", doc)
        code, out, _ = self.run_main([path])
        self.assertEqual(code, 0)
        self.assertIn("gate `adaptive`: PASS", out)

    def test_adaptive_gate_fails_on_zero_fast_path_share(self):
        doc = report(
            bench="adaptive",
            metrics=adaptive_metrics(adaptive_fast_path_share=0.0),
        )
        path = self.write("BENCH_adaptive.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("adaptive_fast_path_share", err)

    def test_adaptive_gate_fails_on_missing_metric(self):
        doc = report(bench="adaptive", metrics={})
        path = self.write("BENCH_adaptive.json", doc)
        code, _, err = self.run_main([path])
        self.assertEqual(code, 1)
        self.assertIn("adaptive_requests_lost", err)


class TestRequire(CheckBenchCase):
    def test_require_fails_on_missing_bench(self):
        path = self.write("BENCH_scheduler.json", report())
        code, _, err = self.run_main(["--require", "scheduler,gossip", path])
        self.assertEqual(code, 1)
        self.assertIn("required bench `gossip` missing", err)

    def test_require_passes_when_all_present(self):
        a = self.write("BENCH_scheduler.json", report())
        b = self.write(
            "BENCH_gossip.json",
            report(
                bench="gossip",
                metrics={
                    "gossip_vs_probe_hit_rate_ratio": 1.0,
                    "probe_calls_per_request_gossip": 0.0,
                },
            ),
        )
        code, _, _ = self.run_main(["--require", "scheduler,gossip", a, b])
        self.assertEqual(code, 0)


class TestDelta(CheckBenchCase):
    def test_delta_prints_percent_changes_and_new_metrics(self):
        base_dir = os.path.join(self.dir, "baseline")
        os.makedirs(base_dir)
        with open(os.path.join(base_dir, "BENCH_scheduler.json"), "w") as f:
            json.dump(report(metrics={"us": 10.0, "gone": 1.0}), f)
        path = self.write(
            "BENCH_scheduler.json",
            report(metrics={"us": 12.0, "fresh": 3.0}),
        )
        code, out, _ = self.run_main(["--delta", base_dir, path])
        self.assertEqual(code, 0)
        self.assertIn("10 -> 12 (+20.0%)", out)
        self.assertIn("fresh: 3 (new metric)", out)
        self.assertIn("gone: dropped (was 1)", out)

    def test_delta_missing_baseline_is_not_fatal(self):
        base_dir = os.path.join(self.dir, "empty-baseline")
        os.makedirs(base_dir)
        path = self.write("BENCH_scheduler.json", report())
        code, out, _ = self.run_main(["--delta", base_dir, path])
        self.assertEqual(code, 0)
        self.assertIn("no baseline for BENCH_scheduler.json", out)


if __name__ == "__main__":
    unittest.main()
