#!/usr/bin/env python3
"""Validate BENCH_*.json reports and enforce the CI bench gates.

This file is the single source of truth for every bench assertion CI
makes — the workflow calls it once instead of carrying inline heredocs,
and it runs identically on a laptop:

    python3 tools/check_bench.py BENCH_*.json
    python3 tools/check_bench.py --require cluster,prefix,chunked BENCH_*.json
    python3 tools/check_bench.py --delta path/to/baseline BENCH_*.json

Modes
-----
* default: schema-validate every given report, print its metrics, and
  apply the per-bench headline gates (below). Exit 1 if anything fails.
* ``--require a,b,c``: additionally fail if no given file carries one of
  the named benches (catches a bench target silently not running).
* ``--delta DIR``: after the gates, print per-metric deltas against the
  same-named reports in DIR (a downloaded ``bench-reports-<sha>``
  artifact from main). Missing baselines are reported, never fatal —
  the delta is a trajectory read-out, not a gate.

Gates (bench name → assertions)
-------------------------------
* ``cluster``: ``p2c_vs_rr_p99_ratio < 1.0`` — power-of-two-choices must
  beat round-robin on p99 (a ratio drifting to 1.0 means the dispatch
  load snapshot went stale or the bench left the saturation regime).
* ``prefix``: ``prefill_tokens_saved_frac > 0`` (the radix cache saved
  something on the prefix-heavy config) and
  ``aff_vs_p2c_hit_rate_delta > 0`` (prefix-affinity routing beats p2c
  on cluster-wide hit rate at R=4).
* ``chunked``: ``p99_decode_stall_ratio_chunked_vs_mono < 1.0`` —
  streaming a long cold header in chunks must cut the p99 per-round
  decode stall versus monolithic prefill.
* ``gossip``: ``gossip_vs_probe_hit_rate_ratio >= 0.95`` — routing on
  advertised prefix digests must preserve at least 95% of the probe
  policy's cluster-wide cache-hit rate at R=4 under eviction pressure —
  and ``probe_calls_per_request_gossip == 0`` — gossip routing must not
  touch the per-replica probe path at all (the dispatch-cost headline).
* ``faults``: ``faults_requests_lost == 0`` — a scripted replica failure
  must lose no requests (every in-flight request on the dead replica is
  re-dispatched to a survivor and completes);
  ``faults_vs_static_p99_ratio < 5.0`` — the fail+restart serve's p99
  end-to-end latency stays within 5x the fault-free serve's (re-prefill
  plus survivor load may stretch the tail, not blow it up); and
  ``rewarm_hit_rate_recovery >= 0.5`` — the cluster cache-hit rate over
  the last quarter of arrivals (after the replica rejoins and re-warms
  via gossip) reaches at least half the pre-failure rate.
* ``serving``: ``serving_requests_lost == 0`` — the loopback
  listen/replay pair must finalize every accepted session (an accepted
  submit that never streams its ``finalized`` event is a lost request);
  ``wall_vs_virtual_p99_ratio < 50.0`` — the live serve's p99 wall e2e
  latency stays within 50x the virtual serve's p99 scaled to wall units
  (virtual p99 × time-scale): stepping granularity, socket hops and
  thread scheduling may stretch the tail at an aggressive time scale,
  not blow it up.
* ``live_faults``: ``live_faults_requests_lost == 0`` — killing a
  replica under the wall-clock listener must lose no sessions (in-flight
  work re-dispatches to survivors without closing client sockets);
  ``live_faults_migrated_sessions >= 1`` — the scripted failure must
  actually hit in-flight sessions (otherwise the loss-free gate is
  vacuous); ``live_faulted_vs_clean_p99_ratio < 10.0`` — the faulted
  replay's p99 wall e2e stays within 10x the clean replay's.
* ``pressure``: ``pressure_requests_lost == 0`` — swapping branches out
  under memory pressure and recomputing them on resume may never drop a
  request; ``pressure_admitted_at_budget_ratio > 1.0`` — by the
  baseline's median admission time, stream-aware admission plus
  reward-driven preemption must have admitted strictly more requests
  than all-or-nothing admission at the same page budget.
* ``adaptive``: ``adaptive_requests_lost == 0`` and
  ``baseline_requests_lost == 0`` — neither the adaptive nor the static
  serve of the mixed workload may drop a request;
  ``adaptive_vs_static_tokens_ratio < 1.0`` — adapting N/M/caps per
  request must strictly cut tokens per request on the mixed easy/hard
  trace; ``adaptive_vs_static_accuracy_delta >= -0.05`` — the token
  savings may cost at most a marginal accuracy dip; and
  ``adaptive_fast_path_share > 0`` — the online easy-classifier must
  route at least one request to the 1-branch no-think fast path
  (the easy traffic exists by construction).
* ``scheduler``: no gate; the ``*_us_per_round`` metrics are printed for
  the trajectory record (absolute values are machine-dependent, and CI
  smoke runs are too noisy to assert the 512-vs-64 ratio ≈ 1.0 — see
  EXPERIMENTS.md §Reading BENCH_scheduler.json).
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

RESULT_FIELDS = ("name", "iters", "mean_us", "p50_us", "p95_us")


class GateFailure(Exception):
    """A report failed validation or a headline assertion."""


def _fail(path: str, msg: str) -> None:
    raise GateFailure(f"{path}: {msg}")


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def load_report(path: str) -> dict:
    """Parse and schema-validate one BENCH_*.json report."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise GateFailure(f"{path}: unreadable or invalid JSON: {e}")
    if not isinstance(doc, dict):
        _fail(path, "top level must be an object")
    for key in ("bench", "results", "metrics"):
        if key not in doc:
            _fail(path, f"missing top-level key `{key}`")
    if not isinstance(doc["bench"], str) or not doc["bench"]:
        _fail(path, "`bench` must be a non-empty string")
    if not isinstance(doc["results"], list):
        _fail(path, "`results` must be an array")
    for i, row in enumerate(doc["results"]):
        if not isinstance(row, dict):
            _fail(path, f"results[{i}] must be an object")
        for field in RESULT_FIELDS:
            if field not in row:
                _fail(path, f"results[{i}] missing `{field}`")
        if not isinstance(row["name"], str) or not row["name"]:
            _fail(path, f"results[{i}].name must be a non-empty string")
        if not _is_num(row["iters"]) or row["iters"] < 1:
            _fail(path, f"results[{i}].iters must be a positive number")
        for field in ("mean_us", "p50_us", "p95_us"):
            v = row[field]
            if not _is_num(v) or not math.isfinite(v) or v < 0:
                _fail(
                    path,
                    f"results[{i}].{field} must be a finite non-negative "
                    f"number, got {v!r}",
                )
    if not isinstance(doc["metrics"], dict):
        _fail(path, "`metrics` must be an object")
    for k, v in doc["metrics"].items():
        if not _is_num(v) or not math.isfinite(v):
            _fail(path, f"metrics[{k!r}] must be a finite number, got {v!r}")
    return doc


def _metric(doc: dict, path: str, key: str) -> float:
    if key not in doc["metrics"]:
        _fail(path, f"gated metric `{key}` missing from `metrics`")
    return float(doc["metrics"][key])


def gate_cluster(doc: dict, path: str) -> None:
    ratio = _metric(doc, path, "p2c_vs_rr_p99_ratio")
    if not ratio < 1.0:
        _fail(
            path,
            f"p2c_vs_rr_p99_ratio = {ratio:.3f}: power-of-two-choices must "
            "beat round-robin on p99 (stale Scheduler::load snapshot, or "
            "the bench left the saturation regime?)",
        )


def gate_prefix(doc: dict, path: str) -> None:
    saved = _metric(doc, path, "prefill_tokens_saved_frac")
    if not saved > 0.0:
        _fail(
            path,
            f"prefill_tokens_saved_frac = {saved:.3f}: the radix cache "
            "saved nothing on the prefix-heavy config (broken lookup or "
            "interning?)",
        )
    delta = _metric(doc, path, "aff_vs_p2c_hit_rate_delta")
    if not delta > 0.0:
        _fail(
            path,
            f"aff_vs_p2c_hit_rate_delta = {delta:.3f}: prefix-affinity "
            "routing must achieve a strictly higher cache-hit rate than "
            "p2c at R=4",
        )


def gate_chunked(doc: dict, path: str) -> None:
    ratio = _metric(doc, path, "p99_decode_stall_ratio_chunked_vs_mono")
    if not ratio < 1.0:
        _fail(
            path,
            f"p99_decode_stall_ratio_chunked_vs_mono = {ratio:.3f}: "
            "chunked prefill must cut the p99 per-round decode stall vs "
            "monolithic (is the per-round budget being honoured, or did "
            "the trace lose its long cold headers?)",
        )


def gate_gossip(doc: dict, path: str) -> None:
    ratio = _metric(doc, path, "gossip_vs_probe_hit_rate_ratio")
    if not ratio >= 0.95:
        _fail(
            path,
            f"gossip_vs_probe_hit_rate_ratio = {ratio:.3f}: digest-table "
            "routing must keep >= 95% of the probe policy's cache-hit rate "
            "(advertisements too stale, or the digest chain diverged from "
            "the radix tree?)",
        )
    probes = _metric(doc, path, "probe_calls_per_request_gossip")
    if probes != 0.0:
        _fail(
            path,
            f"probe_calls_per_request_gossip = {probes:.3f}: gossip routing "
            "must never fall back to per-replica tree probes (the O(R) "
            "dispatch scan is exactly what the digest table removes)",
        )


def gate_faults(doc: dict, path: str) -> None:
    lost = _metric(doc, path, "faults_requests_lost")
    if lost != 0.0:
        _fail(
            path,
            f"faults_requests_lost = {lost:.0f}: a replica failure must be "
            "loss-free — every in-flight request on the dead replica is "
            "re-dispatched to a survivor (did fail_and_drain drop "
            "unfinished work, or the dispatcher skip the drain list?)",
        )
    ratio = _metric(doc, path, "faults_vs_static_p99_ratio")
    if not ratio < 5.0:
        _fail(
            path,
            f"faults_vs_static_p99_ratio = {ratio:.3f}: the fail+restart "
            "serve's p99 e2e latency must stay within 5x the fault-free "
            "serve's (are re-dispatched requests re-queued at the failure "
            "time, or is routing still counting the dead replica?)",
        )
    recovery = _metric(doc, path, "rewarm_hit_rate_recovery")
    if not recovery >= 0.5:
        _fail(
            path,
            f"rewarm_hit_rate_recovery = {recovery:.3f}: after the failed "
            "replica rejoins, the late-trace cache-hit rate must recover "
            "to >= 50% of the pre-failure rate (cold rejoin without a "
            "full-table advertisement, or the retracted digest row never "
            "repopulating?)",
        )


def gate_serving(doc: dict, path: str) -> None:
    lost = _metric(doc, path, "serving_requests_lost")
    if lost != 0.0:
        _fail(
            path,
            f"serving_requests_lost = {lost:.0f}: the loopback replay must "
            "be loss-free — every accepted session streams to its "
            "`finalized` event (did the core drop a session channel, or "
            "the drain loop return before the table emptied?)",
        )
    ratio = _metric(doc, path, "wall_vs_virtual_p99_ratio")
    if not ratio < 50.0:
        _fail(
            path,
            f"wall_vs_virtual_p99_ratio = {ratio:.3f}: the live serve's "
            "p99 wall e2e latency must stay within 50x the virtual p99 "
            "scaled to wall units (is the core loop stalling between "
            "steps, or the pacing clock drifting past the wall target?)",
        )


def gate_live_faults(doc: dict, path: str) -> None:
    lost = _metric(doc, path, "live_faults_requests_lost")
    if lost != 0.0:
        _fail(
            path,
            f"live_faults_requests_lost = {lost:.0f}: a replica failure "
            "under the live listener must be loss-free — every in-flight "
            "session re-dispatches to a survivor without its socket "
            "closing (did the core drop the drain list, or close a "
            "connection on migration?)",
        )
    migrated = _metric(doc, path, "live_faults_migrated_sessions")
    if not migrated >= 1.0:
        _fail(
            path,
            f"live_faults_migrated_sessions = {migrated:.0f}: the scripted "
            "failure hit no in-flight session, so the loss-free gate "
            "proved nothing (did the fault plan fire before arrivals, or "
            "after the burst drained?)",
        )
    ratio = _metric(doc, path, "live_faulted_vs_clean_p99_ratio")
    if not ratio < 10.0:
        _fail(
            path,
            f"live_faulted_vs_clean_p99_ratio = {ratio:.3f}: the faulted "
            "replay's p99 wall e2e must stay within 10x the clean "
            "replay's (are migrated sessions re-queued at the failure "
            "time, or is the core still stepping the dead replica?)",
        )


def gate_pressure(doc: dict, path: str) -> None:
    lost = _metric(doc, path, "pressure_requests_lost")
    if lost != 0.0:
        _fail(
            path,
            f"pressure_requests_lost = {lost:.0f}: memory-pressure serving "
            "must be loss-free — a preempted branch keeps its script "
            "cursor and generated tokens and resumes by recomputation "
            "(did a swap-out drop branch state, or a deferred resume "
            "never retry?)",
        )
    ratio = _metric(doc, path, "pressure_admitted_at_budget_ratio")
    if not ratio > 1.0:
        _fail(
            path,
            f"pressure_admitted_at_budget_ratio = {ratio:.3f}: streamed "
            "admission plus reward-driven preemption must admit strictly "
            "more requests than all-or-nothing admission by the baseline's "
            "median admission time at the same page budget (is the first-"
            "chunk pledge sizing the whole suffix, or preemption finding "
            "no scored candidates?)",
        )


def gate_adaptive(doc: dict, path: str) -> None:
    for key in ("adaptive_requests_lost", "baseline_requests_lost"):
        lost = _metric(doc, path, key)
        if lost != 0.0:
            _fail(
                path,
                f"{key} = {lost:.0f}: the adaptive bench must be loss-free "
                "on both serves — a fast-path or cap-tightened request that "
                "never finalizes is a scheduler hang, not a policy choice "
                "(did a capped answerless request miss the capped-vote "
                "path?)",
            )
    ratio = _metric(doc, path, "adaptive_vs_static_tokens_ratio")
    if not ratio < 1.0:
        _fail(
            path,
            f"adaptive_vs_static_tokens_ratio = {ratio:.3f}: the adaptive "
            "policy must strictly cut tokens per request on the mixed "
            "workload (is the easy-classifier never firing, or spread "
            "pruning finding no concentrated reward sets?)",
        )
    delta = _metric(doc, path, "adaptive_vs_static_accuracy_delta")
    if not delta >= -0.05:
        _fail(
            path,
            f"adaptive_vs_static_accuracy_delta = {delta:.3f}: the token "
            "savings may cost at most 5 accuracy points vs static sart "
            "(is the fast path firing on the hard dataset, or the "
            "tightened cap clipping honest chains?)",
        )
    share = _metric(doc, path, "adaptive_fast_path_share")
    if not share > 0.0:
        _fail(
            path,
            f"adaptive_fast_path_share = {share:.3f}: the mixed workload "
            "contains easy traffic by construction, so the online "
            "classifier must route at least one request to the 1-branch "
            "fast path (are dataset stats never reaching min_samples, or "
            "first-round rewards never recorded?)",
        )


GATES = {
    "cluster": gate_cluster,
    "prefix": gate_prefix,
    "chunked": gate_chunked,
    "gossip": gate_gossip,
    "faults": gate_faults,
    "serving": gate_serving,
    "live_faults": gate_live_faults,
    "pressure": gate_pressure,
    "adaptive": gate_adaptive,
}


def print_metrics(doc: dict) -> None:
    name = doc["bench"]
    for k in sorted(doc["metrics"]):
        print(f"  {name} {k} = {doc['metrics'][k]:.6g}")


def print_delta(doc: dict, path: str, baseline_dir: str) -> None:
    base_path = os.path.join(baseline_dir, os.path.basename(path))
    if not os.path.exists(base_path):
        print(f"  (no baseline for {os.path.basename(path)})")
        return
    try:
        base = load_report(base_path)
    except GateFailure as e:
        print(f"  (baseline unreadable: {e})")
        return
    name = doc["bench"]
    for k in sorted(doc["metrics"]):
        new = doc["metrics"][k]
        if k not in base["metrics"]:
            print(f"  {name} {k}: {new:.6g} (new metric)")
            continue
        old = base["metrics"][k]
        if old != 0:
            pct = 100.0 * (new - old) / abs(old)
            print(f"  {name} {k}: {old:.6g} -> {new:.6g} ({pct:+.1f}%)")
        else:
            print(f"  {name} {k}: {old:.6g} -> {new:.6g}")
    for k in sorted(set(base["metrics"]) - set(doc["metrics"])):
        print(f"  {name} {k}: dropped (was {base['metrics'][k]:.6g})")


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="Validate BENCH_*.json and enforce the CI bench gates."
    )
    ap.add_argument("files", nargs="+", help="BENCH_*.json reports")
    ap.add_argument(
        "--require",
        default="",
        metavar="NAMES",
        help="comma-separated bench names that must be present "
        "(e.g. cluster,prefix,chunked)",
    )
    ap.add_argument(
        "--delta",
        default=None,
        metavar="DIR",
        help="print per-metric deltas vs same-named baseline reports in DIR",
    )
    args = ap.parse_args(argv)

    failures: list[str] = []
    seen: set[str] = set()
    docs: list[tuple[str, dict]] = []
    for path in args.files:
        try:
            doc = load_report(path)
        except GateFailure as e:
            failures.append(str(e))
            continue
        docs.append((path, doc))
        seen.add(doc["bench"])
        print(f"ok: {path} (bench `{doc['bench']}`, "
              f"{len(doc['results'])} result rows, "
              f"{len(doc['metrics'])} metrics)")
        print_metrics(doc)
        gate = GATES.get(doc["bench"])
        if gate is not None:
            try:
                gate(doc, path)
                print(f"  gate `{doc['bench']}`: PASS")
            except GateFailure as e:
                failures.append(str(e))
                print(f"  gate `{doc['bench']}`: FAIL")

    for name in filter(None, args.require.split(",")):
        if name not in seen:
            failures.append(
                f"required bench `{name}` missing from "
                f"{[os.path.basename(f) for f in args.files]}"
            )

    if args.delta is not None:
        print(f"\nper-metric deltas vs baseline `{args.delta}`:")
        for path, doc in docs:
            print_delta(doc, path, args.delta)

    if failures:
        print("\nbench gate failures:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nall bench gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
